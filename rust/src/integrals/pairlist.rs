//! Q-sorted shell-pair lists — screening as a *loop bound* (paper §4.1).
//!
//! The engines' legacy inner loops enumerated every triangular pair
//! ordinal and tested `screened_weighted` per quartet, so a late-SCF ΔD
//! build paid O(N⁴) loop-and-branch overhead just to *skip* work. The
//! paper's structure never tests doomed quartets one by one: shell
//! pairs are ordered by their Schwarz bound, so for a fixed bra pair
//! the ket walk simply *stops* at the first pair whose bound product
//! drops below τ — everything after it is smaller still.
//!
//! [`SortedPairList`] is the SCF-lifetime half of that structure: the
//! surviving canonical pairs (Schwarz-nonzero, with a
//! [`ShellPairStore`] slot) sorted descending by `Q_ij`, built once per
//! SCF next to the store. [`PairWalk`] is the per-build (per-density)
//! half: the density weight `w = max|D|` folds into the bound
//!
//! ```text
//!   visit (ij, kl)  ⟺  Q_ij · Q_kl · w  >  τ         (rank kl ≤ rank ij)
//! ```
//!
//! which factorizes per pair, so the surviving ket range of every bra
//! pair is a *prefix* of the Q-sorted list — found by binary search,
//! walked with zero per-quartet branching. `w` bounds the
//! Häser–Ahlrichs quartet weight (`PairDensityMax::quartet_weight ≤
//! global`), so the visited set is a superset of the per-quartet
//! weighted survivors: accuracy can only improve, and with ΔD densities
//! `w → 0` collapses the walk to nothing.
//!
//! The outer traversal is *not* Q-ordered: tasks are handed out grouped
//! by leading shell `i` (the order the shared-Fock engine's lazy `F_I`
//! flush depends on). Because the active set under any weight is a
//! prefix of the Q-sorted ranks, the per-build task order is a linear
//! *filter* of one precomputed (i, j)-sorted template — no per-build
//! re-sort.

use super::schwarz::{PairDensityMax, SchwarzScreen};
use super::shellpair::ShellPairStore;

/// One surviving shell pair: canonical indices (i ≥ j), its Schwarz
/// bound, and its precomputed-table slot in the [`ShellPairStore`].
#[derive(Debug, Clone, Copy)]
pub struct PairEntry {
    pub i: u32,
    pub j: u32,
    /// Schwarz bound Q_ij = √max|(ij|ij)|.
    pub q: f64,
    /// Table slot in the store ([`ShellPairStore::view_by_slot`]).
    pub slot: u32,
}

/// SCF-lifetime list of surviving shell pairs sorted descending by
/// Schwarz bound. Built once per SCF alongside the [`ShellPairStore`];
/// shared read-only by every engine thread.
#[derive(Debug, Clone)]
pub struct SortedPairList {
    n_shells: usize,
    /// Screening threshold τ the walks are built against (copied from
    /// the [`SchwarzScreen`] this list was derived from).
    tau: f64,
    /// Entries in descending-q order; the index into this vector is the
    /// pair's *rank*.
    entries: Vec<PairEntry>,
    /// `qs[rank] = entries[rank].q` — a dense copy so the binary-search
    /// walks touch one cache-friendly array. Descending; `qs[0]` is the
    /// prefix maximum of every suffix walk.
    qs: Vec<f64>,
    /// All ranks sorted by (i, j) — the outer-traversal template the
    /// per-build [`PairWalk`] filters (see module docs).
    ij_order: Vec<u32>,
}

impl SortedPairList {
    /// Collect the pairs with a nonzero Schwarz bound *and* stored pair
    /// tables, sorted descending by bound. Pairs failing either test
    /// contribute only identically-negligible (or exactly zero-block)
    /// quartets.
    pub fn build(screen: &SchwarzScreen, store: &ShellPairStore) -> SortedPairList {
        let n = screen.n_shells();
        assert_eq!(
            n,
            store.n_shells(),
            "SchwarzScreen and ShellPairStore disagree on shell count"
        );
        let mut entries: Vec<PairEntry> = Vec::new();
        for i in 0..n {
            for j in 0..=i {
                let q = screen.q(i, j);
                if q <= 0.0 {
                    continue;
                }
                let Some(slot) = store.slot(i, j) else {
                    continue;
                };
                entries.push(PairEntry { i: i as u32, j: j as u32, q, slot });
            }
        }
        // Descending q; (i, j) tie-break keeps the rank assignment (and
        // therefore every engine's visited set) deterministic.
        entries.sort_by(|a, b| {
            b.q.partial_cmp(&a.q)
                .expect("Schwarz bounds are finite")
                .then_with(|| (a.i, a.j).cmp(&(b.i, b.j)))
        });
        let qs: Vec<f64> = entries.iter().map(|e| e.q).collect();
        let mut ij_order: Vec<u32> = (0..entries.len() as u32).collect();
        ij_order.sort_by_key(|&r| {
            let e = &entries[r as usize];
            (e.i, e.j)
        });
        SortedPairList { n_shells: n, tau: screen.tau, entries, qs, ij_order }
    }

    /// Number of listed (surviving) pairs.
    #[inline]
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn n_shells(&self) -> usize {
        self.n_shells
    }

    /// The τ this list's walks screen against.
    pub fn tau(&self) -> f64 {
        self.tau
    }

    /// Shell indices (i ≥ j) of the pair at `rank`.
    #[inline]
    pub fn pair(&self, rank: usize) -> (usize, usize) {
        let e = &self.entries[rank];
        (e.i as usize, e.j as usize)
    }

    /// Schwarz bound of the pair at `rank`.
    #[inline]
    pub fn q(&self, rank: usize) -> f64 {
        self.qs[rank]
    }

    /// Store slot of the pair at `rank`.
    #[inline]
    pub fn slot(&self, rank: usize) -> u32 {
        self.entries[rank].slot
    }

    /// Full entry at `rank`.
    #[inline]
    pub fn entry(&self, rank: usize) -> PairEntry {
        self.entries[rank]
    }

    /// Largest Schwarz bound in the list (the rank-0 entry).
    pub fn q_max(&self) -> f64 {
        self.qs.first().copied().unwrap_or(0.0)
    }

    /// Quartets in *list space*: every unordered pair-of-listed-pairs,
    /// m(m+1)/2. The gap between this and a walk's visited count is
    /// what the early exit saved over enumerate-and-test.
    pub fn n_list_quartets(&self) -> u64 {
        let m = self.entries.len() as u64;
        m * (m + 1) / 2
    }

    /// Rank of canonical pair (i ≥ j), if listed. O(m) — for tests and
    /// diagnostics, not hot paths (engines work in rank space).
    pub fn rank_of(&self, i: usize, j: usize) -> Option<usize> {
        let (a, b) = if i >= j { (i, j) } else { (j, i) };
        self.entries
            .iter()
            .position(|e| e.i as usize == a && e.j as usize == b)
    }

    /// Heap footprint in bytes (memory-model accounting).
    pub fn bytes(&self) -> usize {
        Self::estimate_bytes_for(self.entries.len())
    }

    /// Footprint of a list with `n_pairs` entries — the same formula
    /// `bytes()` reports, for footprint predictions that count
    /// survivors without building anything
    /// (`ShellPairStore::estimate_pair_count`).
    pub fn estimate_bytes_for(n_pairs: usize) -> usize {
        std::mem::size_of::<SortedPairList>()
            + n_pairs
                * (std::mem::size_of::<PairEntry>()
                    + std::mem::size_of::<f64>()
                    + std::mem::size_of::<u32>())
    }

    /// Build the per-density walk: fold `dmax`'s global weight into the
    /// bound and materialize the active task order (a linear filter of
    /// the precomputed (i, j) template — no sorting).
    pub fn weighted(&self, dmax: &PairDensityMax) -> PairWalk<'_> {
        let weight = dmax.global;
        let n_active = match self.qs.first() {
            None => 0,
            Some(&q0) => self.qs.partition_point(|&q| q * q0 * weight > self.tau),
        };
        let tasks: Vec<u32> = self
            .ij_order
            .iter()
            .copied()
            .filter(|&r| (r as usize) < n_active)
            .collect();
        PairWalk { list: self, weight, n_active, tasks }
    }
}

/// A density-weighted early-exit view over a [`SortedPairList`] — one
/// Fock build's iteration space. Screening is a *loop bound* here: the
/// surviving ket range of bra rank `r` is `0..kl_limit(r)`, with no
/// per-quartet test inside.
#[derive(Debug, Clone)]
pub struct PairWalk<'a> {
    list: &'a SortedPairList,
    /// Density weight folded into the bound: max |D| over shell blocks
    /// (bounds every Häser–Ahlrichs quartet weight from above).
    weight: f64,
    /// Ranks [0, n_active) have a nonempty ket range; everything at or
    /// beyond n_active is dead against *every* partner — dead bra tasks
    /// are impossible by construction.
    n_active: usize,
    /// The active ranks in (i, j)-grouped order — what the DLB hands
    /// out. `tasks.len() == n_active`.
    tasks: Vec<u32>,
}

impl<'a> PairWalk<'a> {
    /// The list this walk views.
    #[inline]
    pub fn pairs(&self) -> &'a SortedPairList {
        self.list
    }

    /// The density weight folded into the bound.
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// Number of bra tasks (= active ranks). The DLB distributes
    /// ordinals in `0..n_tasks()`; every task has work.
    #[inline]
    pub fn n_tasks(&self) -> usize {
        self.n_active
    }

    /// The q-rank of task ordinal `t` (tasks are (i, j)-grouped so the
    /// shared-Fock lazy F_I flush sees monotone `i`).
    #[inline]
    pub fn task(&self, t: usize) -> usize {
        self.tasks[t] as usize
    }

    /// Early-exit loop bound of bra rank `rij`: the number of leading
    /// ket ranks surviving `q_ij·q_kl·w > τ`, capped by the triangular
    /// constraint `rkl ≤ rij`. Binary search over the descending-q
    /// prefix — the single place the bound is evaluated.
    #[inline]
    pub fn kl_limit(&self, rij: usize) -> usize {
        let qij = self.list.qs[rij];
        let (w, tau) = (self.weight, self.list.tau);
        self.list.qs[..=rij].partition_point(|&qkl| qij * qkl * w > tau)
    }

    /// Does the walk visit the rank pair {ra, rb}? (Order-free; for
    /// property tests.)
    pub fn visits(&self, ra: usize, rb: usize) -> bool {
        let (hi, lo) = if ra >= rb { (ra, rb) } else { (rb, ra) };
        hi < self.n_active && lo < self.kl_limit(hi)
    }

    /// Total quartets the walk visits (= every engine's
    /// `quartets_computed` for this build).
    pub fn n_visited(&self) -> u64 {
        (0..self.n_active).map(|r| self.kl_limit(r) as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::{BasisName, BasisSet};
    use crate::chem::molecules;
    use crate::linalg::Matrix;
    use crate::util::prng::Rng;

    fn setup(
        mol: &crate::chem::Molecule,
        tau: f64,
    ) -> (BasisSet, ShellPairStore, SchwarzScreen) {
        let basis = BasisSet::assemble(mol, BasisName::Sto3g).unwrap();
        let store = ShellPairStore::build(&basis);
        let screen = SchwarzScreen::build_with_store(&basis, &store, tau);
        (basis, store, screen)
    }

    fn random_density(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = rng.range(-0.5, 0.5);
                d.set(i, j, x);
                d.set(j, i, x);
            }
        }
        d
    }

    #[test]
    fn list_is_sorted_canonical_and_slotted() {
        let (basis, store, screen) = setup(&molecules::water(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        assert!(!list.is_empty());
        assert_eq!(list.n_shells(), basis.n_shells());
        for r in 0..list.len() {
            let (i, j) = list.pair(r);
            assert!(i >= j, "rank {r}: non-canonical ({i},{j})");
            assert!(list.q(r) > 0.0);
            assert_eq!(list.q(r), screen.q(i, j));
            // The slot resolves to this pair's tables.
            assert_eq!(store.slot(i, j), Some(list.slot(r)));
            if r > 0 {
                assert!(list.q(r) <= list.q(r - 1), "not descending at {r}");
            }
        }
        assert_eq!(list.q_max(), list.q(0));
        assert!(list.bytes() > 0);
    }

    #[test]
    fn far_pairs_are_not_listed() {
        let mut mol = molecules::h2();
        mol.atoms[1].pos[2] = 100.0;
        let basis = BasisSet::assemble(&mol, BasisName::Sto3g).unwrap();
        let store = ShellPairStore::build(&basis);
        let screen = SchwarzScreen::build_with_store(&basis, &store, 1e-10);
        let list = SortedPairList::build(&screen, &store);
        assert_eq!(list.rank_of(1, 0), None, "negligible pair must be unlisted");
        assert!(list.rank_of(0, 0).is_some());
        assert!(list.rank_of(1, 1).is_some());
    }

    #[test]
    fn walk_tasks_are_i_grouped_and_active() {
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 11);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        assert!(walk.n_tasks() > 0);
        assert!(walk.n_tasks() <= list.len());
        let mut prev = (0usize, 0usize);
        for t in 0..walk.n_tasks() {
            let r = walk.task(t);
            // Every handed-out task has work: dead bra tasks are
            // impossible by construction.
            assert!(walk.kl_limit(r) > 0, "task {t} (rank {r}) is dead");
            let ij = list.pair(r);
            if t > 0 {
                assert!(ij >= prev, "tasks not (i,j)-grouped at {t}");
            }
            prev = ij;
        }
    }

    #[test]
    fn kl_limit_matches_linear_scan() {
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-9);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 23);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        let w = walk.weight();
        for rij in (0..list.len()).step_by(7) {
            let mut expect = 0usize;
            for rkl in 0..=rij {
                if list.q(rij) * list.q(rkl) * w > list.tau() {
                    expect += 1;
                } else {
                    break; // descending q: nothing later survives
                }
            }
            assert_eq!(walk.kl_limit(rij), expect, "rij={rij}");
        }
    }

    #[test]
    fn visited_set_is_exact_bound_set() {
        // Brute force over every rank pair: visited ⟺ bound survives.
        let (basis, store, screen) = setup(&molecules::water(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 5);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        let mut visited = 0u64;
        for ra in 0..list.len() {
            for rb in 0..=ra {
                let expect = list.q(ra) * list.q(rb) * walk.weight() > list.tau();
                assert_eq!(walk.visits(ra, rb), expect, "({ra},{rb})");
                if expect {
                    visited += 1;
                }
            }
        }
        assert_eq!(walk.n_visited(), visited);
        assert!(visited <= list.n_list_quartets());
    }

    #[test]
    fn zero_weight_kills_everything() {
        let (basis, store, screen) = setup(&molecules::water(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let d = Matrix::zeros(basis.n_bf, basis.n_bf);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        assert_eq!(walk.n_tasks(), 0);
        assert_eq!(walk.n_visited(), 0);
    }

    #[test]
    fn shrinking_weight_shrinks_the_walk() {
        // ΔD → 0 is the whole point: smaller weights must visit
        // (weakly) fewer quartets, collapsing to zero.
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let mut last = u64::MAX;
        for scale in [1.0, 1e-3, 1e-6, 1e-9, 1e-12] {
            let mut d = Matrix::identity(basis.n_bf);
            d.scale(scale);
            let dmax = PairDensityMax::build(&basis, &d);
            let visited = list.weighted(&dmax).n_visited();
            assert!(visited <= last, "scale {scale}: {visited} > {last}");
            last = visited;
        }
        // q_max² · 1e-12 is far below the default τ = 1e-10.
        assert_eq!(last, 0, "1e-12-scale density must screen out everything");
    }
}

//! Q-sorted shell-pair lists — screening as a *loop bound* (paper §4.1).
//!
//! The engines' legacy inner loops enumerated every triangular pair
//! ordinal and tested `screened_weighted` per quartet, so a late-SCF ΔD
//! build paid O(N⁴) loop-and-branch overhead just to *skip* work. The
//! paper's structure never tests doomed quartets one by one: shell
//! pairs are ordered by their Schwarz bound, so for a fixed bra pair
//! the ket walk simply *stops* at the first pair whose bound product
//! drops below τ — everything after it is smaller still.
//!
//! [`SortedPairList`] is the SCF-lifetime half of that structure: the
//! surviving canonical pairs (Schwarz-nonzero, with a
//! [`ShellPairStore`] slot) sorted descending by `Q_ij`, built once per
//! SCF next to the store. [`PairWalk`] is the per-build (per-density)
//! half: the density weight `w = max|D|` folds into the bound
//!
//! ```text
//!   visit (ij, kl)  ⟺  Q_ij · Q_kl · w  >  τ         (rank kl ≤ rank ij)
//! ```
//!
//! which factorizes per pair, so the surviving ket range of every bra
//! pair is a *prefix* of the Q-sorted list — found by binary search,
//! walked with zero per-quartet branching. `w` bounds the
//! Häser–Ahlrichs quartet weight (`PairDensityMax::quartet_weight ≤
//! global`), so the visited set is a superset of the per-quartet
//! weighted survivors: accuracy can only improve, and with ΔD densities
//! `w → 0` collapses the walk to nothing.
//!
//! The outer traversal is *not* Q-ordered: tasks are handed out grouped
//! by leading shell `i` (the order the shared-Fock engine's lazy `F_I`
//! flush depends on). Because the active set under any weight is a
//! prefix of the Q-sorted ranks, the per-build task order is a linear
//! *filter* of one precomputed (i, j)-sorted template — no per-build
//! re-sort.

use super::schwarz::{PairDensityMax, SchwarzScreen};
use super::shellpair::{ShellPairStore, StoreShard};

/// One surviving shell pair: canonical indices (i ≥ j), its Schwarz
/// bound, and its precomputed-table slot in the [`ShellPairStore`].
#[derive(Debug, Clone, Copy)]
pub struct PairEntry {
    pub i: u32,
    pub j: u32,
    /// Schwarz bound Q_ij = √max|(ij|ij)|.
    pub q: f64,
    /// Table slot in the store ([`ShellPairStore::view_by_slot`]).
    pub slot: u32,
}

/// SCF-lifetime list of surviving shell pairs sorted descending by
/// Schwarz bound. Built once per SCF alongside the [`ShellPairStore`];
/// shared read-only by every engine thread.
#[derive(Debug, Clone)]
pub struct SortedPairList {
    n_shells: usize,
    /// Screening threshold τ the walks are built against (copied from
    /// the [`SchwarzScreen`] this list was derived from).
    tau: f64,
    /// Entries in descending-q order; the index into this vector is the
    /// pair's *rank*.
    entries: Vec<PairEntry>,
    /// `qs[rank] = entries[rank].q` — a dense copy so the binary-search
    /// walks touch one cache-friendly array. Descending; `qs[0]` is the
    /// prefix maximum of every suffix walk.
    qs: Vec<f64>,
    /// All ranks sorted by (i, j) — the outer-traversal template the
    /// per-build [`PairWalk`] filters (see module docs).
    ij_order: Vec<u32>,
}

impl SortedPairList {
    /// Collect the pairs with a nonzero Schwarz bound *and* stored pair
    /// tables, sorted descending by bound. Pairs failing either test
    /// contribute only identically-negligible (or exactly zero-block)
    /// quartets.
    pub fn build(screen: &SchwarzScreen, store: &ShellPairStore) -> SortedPairList {
        let n = screen.n_shells();
        assert_eq!(
            n,
            store.n_shells(),
            "SchwarzScreen and ShellPairStore disagree on shell count"
        );
        let mut entries: Vec<PairEntry> = Vec::new();
        for i in 0..n {
            for j in 0..=i {
                let q = screen.q(i, j);
                if q <= 0.0 {
                    continue;
                }
                let Some(slot) = store.slot(i, j) else {
                    continue;
                };
                entries.push(PairEntry { i: i as u32, j: j as u32, q, slot });
            }
        }
        // Descending q; (i, j) tie-break keeps the rank assignment (and
        // therefore every engine's visited set) deterministic.
        entries.sort_by(|a, b| {
            b.q.partial_cmp(&a.q)
                .expect("Schwarz bounds are finite")
                .then_with(|| (a.i, a.j).cmp(&(b.i, b.j)))
        });
        let qs: Vec<f64> = entries.iter().map(|e| e.q).collect();
        let mut ij_order: Vec<u32> = (0..entries.len() as u32).collect();
        ij_order.sort_by_key(|&r| {
            let e = &entries[r as usize];
            (e.i, e.j)
        });
        SortedPairList { n_shells: n, tau: screen.tau, entries, qs, ij_order }
    }

    /// Number of listed (surviving) pairs.
    #[inline]
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn n_shells(&self) -> usize {
        self.n_shells
    }

    /// The τ this list's walks screen against.
    pub fn tau(&self) -> f64 {
        self.tau
    }

    /// Shell indices (i ≥ j) of the pair at `rank`.
    #[inline]
    pub fn pair(&self, rank: usize) -> (usize, usize) {
        let e = &self.entries[rank];
        (e.i as usize, e.j as usize)
    }

    /// Schwarz bound of the pair at `rank`.
    #[inline]
    pub fn q(&self, rank: usize) -> f64 {
        self.qs[rank]
    }

    /// Store slot of the pair at `rank`.
    #[inline]
    pub fn slot(&self, rank: usize) -> u32 {
        self.entries[rank].slot
    }

    /// Full entry at `rank`.
    #[inline]
    pub fn entry(&self, rank: usize) -> PairEntry {
        self.entries[rank]
    }

    /// Largest Schwarz bound in the list (the rank-0 entry).
    pub fn q_max(&self) -> f64 {
        self.qs.first().copied().unwrap_or(0.0)
    }

    /// Quartets in *list space*: every unordered pair-of-listed-pairs,
    /// m(m+1)/2. The gap between this and a walk's visited count is
    /// what the early exit saved over enumerate-and-test.
    pub fn n_list_quartets(&self) -> u64 {
        let m = self.entries.len() as u64;
        m * (m + 1) / 2
    }

    /// Rank of canonical pair (i ≥ j), if listed. O(m) — for tests and
    /// diagnostics, not hot paths (engines work in rank space).
    pub fn rank_of(&self, i: usize, j: usize) -> Option<usize> {
        let (a, b) = if i >= j { (i, j) } else { (j, i) };
        self.entries
            .iter()
            .position(|e| e.i as usize == a && e.j as usize == b)
    }

    /// Heap footprint in bytes (memory-model accounting).
    pub fn bytes(&self) -> usize {
        Self::estimate_bytes_for(self.entries.len())
    }

    /// Footprint of a list with `n_pairs` entries — the same formula
    /// `bytes()` reports, for footprint predictions that count
    /// survivors without building anything
    /// (`ShellPairStore::estimate_pair_count`).
    pub fn estimate_bytes_for(n_pairs: usize) -> usize {
        std::mem::size_of::<SortedPairList>()
            + n_pairs
                * (std::mem::size_of::<PairEntry>()
                    + std::mem::size_of::<f64>()
                    + std::mem::size_of::<u32>())
    }

    /// Early-exit loop bound of bra rank `rij` at an explicit density
    /// weight: the number of leading ket ranks surviving
    /// `q_ij·q_kl·weight > τ`, capped by the triangular constraint
    /// `rkl ≤ rij`. [`PairWalk::kl_limit`] is this at the walk's weight;
    /// [`StoreSharding`] uses it directly to size each shard's resident
    /// ket prefix.
    #[inline]
    pub fn kl_limit_at(&self, rij: usize, weight: f64) -> usize {
        let qij = self.qs[rij];
        self.qs[..=rij].partition_point(|&qkl| qij * qkl * weight > self.tau)
    }

    /// Build the per-density walk: fold `dmax`'s global weight into the
    /// bound and materialize the active task order (a linear filter of
    /// the precomputed (i, j) template — no sorting).
    pub fn weighted(&self, dmax: &PairDensityMax) -> PairWalk<'_> {
        let weight = dmax.global;
        let n_active = match self.qs.first() {
            None => 0,
            Some(&q0) => self.qs.partition_point(|&q| q * q0 * weight > self.tau),
        };
        let tasks: Vec<u32> = self
            .ij_order
            .iter()
            .copied()
            .filter(|&r| (r as usize) < n_active)
            .collect();
        PairWalk { list: self, weight, n_active, tasks }
    }
}

/// A density-weighted early-exit view over a [`SortedPairList`] — one
/// Fock build's iteration space. Screening is a *loop bound* here: the
/// surviving ket range of bra rank `r` is `0..kl_limit(r)`, with no
/// per-quartet test inside.
#[derive(Debug, Clone)]
pub struct PairWalk<'a> {
    list: &'a SortedPairList,
    /// Density weight folded into the bound: max |D| over shell blocks
    /// (bounds every Häser–Ahlrichs quartet weight from above).
    weight: f64,
    /// Ranks [0, n_active) have a nonempty ket range; everything at or
    /// beyond n_active is dead against *every* partner — dead bra tasks
    /// are impossible by construction.
    n_active: usize,
    /// The active ranks in (i, j)-grouped order — what the DLB hands
    /// out. `tasks.len() == n_active`.
    tasks: Vec<u32>,
}

impl<'a> PairWalk<'a> {
    /// The list this walk views.
    #[inline]
    pub fn pairs(&self) -> &'a SortedPairList {
        self.list
    }

    /// The density weight folded into the bound.
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// Number of bra tasks (= active ranks). The DLB distributes
    /// ordinals in `0..n_tasks()`; every task has work.
    #[inline]
    pub fn n_tasks(&self) -> usize {
        self.n_active
    }

    /// The q-rank of task ordinal `t` (tasks are (i, j)-grouped so the
    /// shared-Fock lazy F_I flush sees monotone `i`).
    #[inline]
    pub fn task(&self, t: usize) -> usize {
        self.tasks[t] as usize
    }

    /// Early-exit loop bound of bra rank `rij`: the number of leading
    /// ket ranks surviving `q_ij·q_kl·w > τ`, capped by the triangular
    /// constraint `rkl ≤ rij`. Binary search over the descending-q
    /// prefix ([`SortedPairList::kl_limit_at`] at the walk's weight).
    #[inline]
    pub fn kl_limit(&self, rij: usize) -> usize {
        self.list.kl_limit_at(rij, self.weight)
    }

    /// Does the walk visit the rank pair {ra, rb}? (Order-free; for
    /// property tests.)
    pub fn visits(&self, ra: usize, rb: usize) -> bool {
        let (hi, lo) = if ra >= rb { (ra, rb) } else { (rb, ra) };
        hi < self.n_active && lo < self.kl_limit(hi)
    }

    /// Total quartets the walk visits (= every engine's
    /// `quartets_computed` for this build).
    pub fn n_visited(&self) -> u64 {
        (0..self.n_active).map(|r| self.kl_limit(r) as u64).sum()
    }
}

/// Contiguous partition bounds over per-item byte weights, balanced by
/// cumulative bytes: shard `s` owns items `[bounds[s], bounds[s+1])`,
/// ending at the first index where the running total reaches
/// `s/n_shards` of the grand total (so the largest shard holds the even
/// share plus at most one item of slack). The single partition rule
/// shared by [`StoreSharding::build`] and the cluster simulator's
/// shard model — one implementation, no drift between the engines'
/// sharding and the memory gate's model of it.
pub fn balanced_bounds(bytes: &[u64], n_shards: usize) -> Vec<usize> {
    assert!(n_shards > 0, "need at least one shard");
    let m = bytes.len();
    let total: u128 = bytes.iter().map(|&b| b as u128).sum();
    let mut bounds = Vec::with_capacity(n_shards + 1);
    bounds.push(0usize);
    let mut acc = 0u128;
    let mut r = 0usize;
    for s in 1..=n_shards {
        let target = total * s as u128 / n_shards as u128;
        while r < m && acc < target {
            acc += bytes[r] as u128;
            r += 1;
        }
        bounds.push(if s == n_shards { m } else { r });
    }
    bounds
}

/// Run-level summary of a [`StoreSharding`] for `ScfResult` / the CLI.
#[derive(Debug, Clone)]
pub struct ShardingReport {
    pub n_shards: usize,
    /// Largest private per-rank shard footprint (owned bra tables +
    /// slot remap) — the number the acceptance gate compares against
    /// the replicated store.
    pub max_shard_bytes: usize,
    /// Mean private shard footprint.
    pub mean_shard_bytes: usize,
    /// Length (pairs) of the union of all shards' resident ket
    /// prefixes. Prefixes nest (all start at rank 0), so this window,
    /// held **once per node**, serves every shard.
    pub prefix_len: usize,
    /// Bytes of that shared prefix window's tables.
    pub prefix_bytes: usize,
    /// Non-resident lookups served so far across all shards
    /// (work-stealing traffic).
    pub remote_fetches: u64,
}

/// Partition of a [`ShellPairStore`] across virtual ranks — the paper's
/// share-don't-replicate lever (§6.2, Table 2) applied to integral pair
/// data.
///
/// The surviving bra pairs of the Q-sorted list are split into
/// `n_shards` **contiguous rank ranges**, balanced by table bytes.
/// Contiguity in Q-rank keeps the early-exit walk semantics untouched:
/// a shard's bra tasks are exactly the walk tasks whose rank falls in
/// its range, and each bra's surviving ket range is still the same
/// binary-searched prefix of the global order.
///
/// Each shard's resident set is its owned range plus the ket prefix
/// `[0, P_s)` its bra walks touch at the sharding weight
/// (`P_s = max over owned ranks of kl_limit_at(r, weight)`, capped at
/// the range start — kets inside the range are owned already). Because
/// the triangular constraint bounds `kl_limit(r) ≤ r + 1`, a shard
/// never needs kets beyond its own range end, and all prefixes nest at
/// rank 0 — which is why the memory model holds **one** shared prefix
/// window per node while every rank owns only its private bra shard.
///
/// Built once per SCF next to the list; walks with weights at or below
/// the sharding weight stay fully resident, larger ones (a ΔD spike)
/// spill into counted remote fetches without affecting correctness.
#[derive(Debug)]
pub struct StoreSharding<'a> {
    list: &'a SortedPairList,
    store: &'a ShellPairStore,
    weight: f64,
    /// Shard `s` owns ranks `[bounds[s], bounds[s+1])`.
    bounds: Vec<usize>,
    /// Per-shard resident ket prefix lengths (ranks `[0, prefix[s])`,
    /// always ≤ `bounds[s]`).
    prefix: Vec<usize>,
    shards: Vec<StoreShard<'a>>,
}

impl<'a> StoreSharding<'a> {
    /// Shard `list`'s ranks over `n_shards` virtual ranks, sizing each
    /// resident ket prefix at `weight` (callers pass the first full
    /// build's density weight; 1.0 is a reasonable default for
    /// accounting studies).
    pub fn build(
        list: &'a SortedPairList,
        store: &'a ShellPairStore,
        n_shards: usize,
        weight: f64,
    ) -> StoreSharding<'a> {
        assert!(n_shards > 0, "need at least one shard");
        assert_eq!(
            list.n_shells(),
            store.n_shells(),
            "SortedPairList and ShellPairStore disagree on shell count"
        );
        let m = list.len();
        let bytes: Vec<u64> =
            (0..m).map(|r| store.table_bytes_at(list.slot(r)) as u64).collect();

        // Contiguous split balanced by cumulative table bytes — the
        // shared rule, also used by the simulator's shard model.
        let bounds = balanced_bounds(&bytes, n_shards);

        // Resident ket prefix per shard: the furthest ket any owned bra
        // walks at the sharding weight, clipped to the range start.
        let mut prefix = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let (lo, hi) = (bounds[s], bounds[s + 1]);
            let mut p = 0usize;
            for rank in lo..hi {
                p = p.max(list.kl_limit_at(rank, weight).min(lo));
            }
            prefix.push(p);
        }

        let shards = (0..n_shards)
            .map(|s| {
                StoreShard::new(
                    store,
                    (bounds[s]..bounds[s + 1]).map(|rank| list.slot(rank)),
                    (0..prefix[s]).map(|rank| list.slot(rank)),
                )
            })
            .collect();

        StoreSharding { list, store, weight, bounds, prefix, shards }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The list this sharding partitions.
    pub fn list(&self) -> &'a SortedPairList {
        self.list
    }

    /// The weight the resident prefixes were sized at.
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// The shard owning bra rank `rank`.
    #[inline]
    pub fn shard_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.list.len());
        self.bounds.partition_point(|&b| b <= rank) - 1
    }

    /// Owned rank range of shard `s`.
    pub fn rank_range(&self, s: usize) -> (usize, usize) {
        (self.bounds[s], self.bounds[s + 1])
    }

    /// Resident ket prefix length of shard `s`.
    pub fn prefix_len(&self, s: usize) -> usize {
        self.prefix[s]
    }

    /// The resident store view of shard `s`.
    #[inline]
    pub fn shard(&self, s: usize) -> &StoreShard<'a> {
        &self.shards[s]
    }

    /// Split a walk's bra tasks by shard ownership, preserving the
    /// (i, j)-grouped task order inside each shard (a filter of the
    /// walk's order). The lists partition the walk's tasks: feeding
    /// them to a [`ShardedDlb`](crate::hf::dlb::ShardedDlb) hands every
    /// task out exactly once.
    pub fn partition_tasks(&self, walk: &PairWalk) -> Vec<Vec<u32>> {
        assert!(
            std::ptr::eq(walk.pairs(), self.list),
            "walk and sharding must view the same SortedPairList"
        );
        let mut out = vec![Vec::new(); self.n_shards()];
        for t in 0..walk.n_tasks() {
            let r = walk.task(t);
            out[self.shard_of(r)].push(r as u32);
        }
        out
    }

    /// Run-level accounting summary.
    pub fn report(&self) -> ShardingReport {
        let n = self.n_shards();
        let max_shard_bytes =
            self.shards.iter().map(|s| s.bytes()).max().unwrap_or(0);
        let mean_shard_bytes =
            self.shards.iter().map(|s| s.bytes()).sum::<usize>() / n;
        let prefix_len = self.prefix.iter().copied().max().unwrap_or(0);
        let prefix_bytes = (0..prefix_len)
            .map(|rank| self.store.table_bytes_at(self.list.slot(rank)))
            .sum();
        let remote_fetches = self.shards.iter().map(|s| s.remote_fetches()).sum();
        ShardingReport {
            n_shards: n,
            max_shard_bytes,
            mean_shard_bytes,
            prefix_len,
            prefix_bytes,
            remote_fetches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::{BasisName, BasisSet};
    use crate::chem::molecules;
    use crate::linalg::Matrix;
    use crate::util::prng::Rng;

    fn setup(
        mol: &crate::chem::Molecule,
        tau: f64,
    ) -> (BasisSet, ShellPairStore, SchwarzScreen) {
        let basis = BasisSet::assemble(mol, BasisName::Sto3g).unwrap();
        let store = ShellPairStore::build(&basis);
        let screen = SchwarzScreen::build_with_store(&basis, &store, tau);
        (basis, store, screen)
    }

    fn random_density(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let x = rng.range(-0.5, 0.5);
                d.set(i, j, x);
                d.set(j, i, x);
            }
        }
        d
    }

    #[test]
    fn list_is_sorted_canonical_and_slotted() {
        let (basis, store, screen) = setup(&molecules::water(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        assert!(!list.is_empty());
        assert_eq!(list.n_shells(), basis.n_shells());
        for r in 0..list.len() {
            let (i, j) = list.pair(r);
            assert!(i >= j, "rank {r}: non-canonical ({i},{j})");
            assert!(list.q(r) > 0.0);
            assert_eq!(list.q(r), screen.q(i, j));
            // The slot resolves to this pair's tables.
            assert_eq!(store.slot(i, j), Some(list.slot(r)));
            if r > 0 {
                assert!(list.q(r) <= list.q(r - 1), "not descending at {r}");
            }
        }
        assert_eq!(list.q_max(), list.q(0));
        assert!(list.bytes() > 0);
    }

    #[test]
    fn far_pairs_are_not_listed() {
        let mut mol = molecules::h2();
        mol.atoms[1].pos[2] = 100.0;
        let basis = BasisSet::assemble(&mol, BasisName::Sto3g).unwrap();
        let store = ShellPairStore::build(&basis);
        let screen = SchwarzScreen::build_with_store(&basis, &store, 1e-10);
        let list = SortedPairList::build(&screen, &store);
        assert_eq!(list.rank_of(1, 0), None, "negligible pair must be unlisted");
        assert!(list.rank_of(0, 0).is_some());
        assert!(list.rank_of(1, 1).is_some());
    }

    #[test]
    fn walk_tasks_are_i_grouped_and_active() {
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 11);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        assert!(walk.n_tasks() > 0);
        assert!(walk.n_tasks() <= list.len());
        let mut prev = (0usize, 0usize);
        for t in 0..walk.n_tasks() {
            let r = walk.task(t);
            // Every handed-out task has work: dead bra tasks are
            // impossible by construction.
            assert!(walk.kl_limit(r) > 0, "task {t} (rank {r}) is dead");
            let ij = list.pair(r);
            if t > 0 {
                assert!(ij >= prev, "tasks not (i,j)-grouped at {t}");
            }
            prev = ij;
        }
    }

    #[test]
    fn kl_limit_matches_linear_scan() {
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-9);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 23);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        let w = walk.weight();
        for rij in (0..list.len()).step_by(7) {
            let mut expect = 0usize;
            for rkl in 0..=rij {
                if list.q(rij) * list.q(rkl) * w > list.tau() {
                    expect += 1;
                } else {
                    break; // descending q: nothing later survives
                }
            }
            assert_eq!(walk.kl_limit(rij), expect, "rij={rij}");
        }
    }

    #[test]
    fn visited_set_is_exact_bound_set() {
        // Brute force over every rank pair: visited ⟺ bound survives.
        let (basis, store, screen) = setup(&molecules::water(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 5);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        let mut visited = 0u64;
        for ra in 0..list.len() {
            for rb in 0..=ra {
                let expect = list.q(ra) * list.q(rb) * walk.weight() > list.tau();
                assert_eq!(walk.visits(ra, rb), expect, "({ra},{rb})");
                if expect {
                    visited += 1;
                }
            }
        }
        assert_eq!(walk.n_visited(), visited);
        assert!(visited <= list.n_list_quartets());
    }

    #[test]
    fn sharding_partitions_ranks_and_balances_bytes() {
        let (_, store, screen) = setup(&molecules::benzene(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let n_shards = 4;
        let sh = StoreSharding::build(&list, &store, n_shards, 1.0);
        assert_eq!(sh.n_shards(), n_shards);
        // Ranges are contiguous, cover [0, m), and shard_of agrees.
        let mut covered = 0usize;
        for s in 0..n_shards {
            let (lo, hi) = sh.rank_range(s);
            assert_eq!(lo, covered);
            covered = hi;
            for r in lo..hi {
                assert_eq!(sh.shard_of(r), s, "rank {r}");
            }
            // The prefix never reaches into the shard's own range.
            assert!(sh.prefix_len(s) <= lo);
        }
        assert_eq!(covered, list.len());
        // Byte balance: every private shard stays well under the
        // replicated store (the acceptance bound is max ≤ 0.5x at 4
        // shards; the partition targets ~0.25x plus one pair of slack).
        let rep = sh.report();
        assert!(rep.max_shard_bytes > 0);
        assert!(
            rep.max_shard_bytes * 2 <= store.bytes(),
            "max shard {} vs replicated {}",
            rep.max_shard_bytes,
            store.bytes()
        );
        assert!(rep.mean_shard_bytes <= rep.max_shard_bytes);
        // Owned tables across shards + shared prefix window never
        // exceed one replicated copy (prefix tables are a subset of the
        // early shards' owned tables, counted once).
        let owned_tables: usize = (0..n_shards)
            .map(|s| {
                let (lo, hi) = sh.rank_range(s);
                (lo..hi).map(|r| store.table_bytes_at(list.slot(r))).sum::<usize>()
            })
            .sum();
        assert!(rep.prefix_bytes <= owned_tables);
        assert_eq!(rep.remote_fetches, 0);
    }

    #[test]
    fn shard_residency_covers_own_walk() {
        // At the sharding weight, every ket a shard's bra tasks touch
        // must be resident (owned range or shared prefix) — no remote
        // fetch on un-stolen work.
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-9);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 3);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        let sh = StoreSharding::build(&list, &store, 3, walk.weight());
        for s in 0..sh.n_shards() {
            let shard = sh.shard(s);
            let (lo, hi) = sh.rank_range(s);
            for rij in lo..hi {
                assert!(shard.is_resident(list.slot(rij)), "own bra {rij}");
                for rkl in 0..walk.kl_limit(rij) {
                    assert!(
                        shard.is_resident(list.slot(rkl)),
                        "shard {s}: bra {rij} touches non-resident ket {rkl}"
                    );
                }
            }
        }
    }

    #[test]
    fn partition_tasks_covers_walk_exactly_once() {
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let d = random_density(basis.n_bf, 29);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        let sh = StoreSharding::build(&list, &store, 4, walk.weight());
        let parts = sh.partition_tasks(&walk);
        assert_eq!(parts.len(), 4);
        let mut all: Vec<u32> = parts.iter().flatten().copied().collect();
        assert_eq!(all.len(), walk.n_tasks(), "task lists must partition the walk");
        all.sort_unstable();
        let mut want: Vec<u32> = (0..walk.n_tasks()).map(|t| walk.task(t) as u32).collect();
        want.sort_unstable();
        assert_eq!(all, want);
        // Ownership: each list's ranks fall in its shard's range.
        for (s, part) in parts.iter().enumerate() {
            let (lo, hi) = sh.rank_range(s);
            for &r in part {
                assert!((r as usize) >= lo && (r as usize) < hi);
            }
        }
    }

    #[test]
    fn single_shard_degenerates_to_replicated() {
        let (_, store, screen) = setup(&molecules::water(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let sh = StoreSharding::build(&list, &store, 1, 1.0);
        let rep = sh.report();
        assert_eq!(rep.n_shards, 1);
        assert_eq!(sh.rank_range(0), (0, list.len()));
        // One shard owns every listed table; no shared prefix needed.
        assert_eq!(rep.prefix_len, 0);
        assert_eq!(rep.prefix_bytes, 0);
        assert_eq!(rep.max_shard_bytes, rep.mean_shard_bytes);
    }

    #[test]
    fn zero_weight_kills_everything() {
        let (basis, store, screen) = setup(&molecules::water(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let d = Matrix::zeros(basis.n_bf, basis.n_bf);
        let dmax = PairDensityMax::build(&basis, &d);
        let walk = list.weighted(&dmax);
        assert_eq!(walk.n_tasks(), 0);
        assert_eq!(walk.n_visited(), 0);
    }

    #[test]
    fn shrinking_weight_shrinks_the_walk() {
        // ΔD → 0 is the whole point: smaller weights must visit
        // (weakly) fewer quartets, collapsing to zero.
        let (basis, store, screen) = setup(&molecules::benzene(), 1e-10);
        let list = SortedPairList::build(&screen, &store);
        let mut last = u64::MAX;
        for scale in [1.0, 1e-3, 1e-6, 1e-9, 1e-12] {
            let mut d = Matrix::identity(basis.n_bf);
            d.scale(scale);
            let dmax = PairDensityMax::build(&basis, &d);
            let visited = list.weighted(&dmax).n_visited();
            assert!(visited <= last, "scale {scale}: {visited} > {last}");
            last = visited;
        }
        // q_max² · 1e-12 is far below the default τ = 1e-10.
        assert_eq!(last, 0, "1e-12-scale density must screen out everything");
    }
}

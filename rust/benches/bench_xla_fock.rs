//! Microbenchmark: the XLA/PJRT dense Fock path (Layer 1+2 artifacts)
//! vs the direct sparse engine on small molecules — the §Perf L2
//! measurement.
//!
//! Run: cargo bench --bench bench_xla_fock   (needs `make artifacts`)

use khf::basis::{BasisName, BasisSet};
use khf::chem::molecules;
use khf::coordinator::report;
use khf::hf::serial::SerialFock;
use khf::hf::{FockBuilder, FockContext};
use khf::integrals::{SchwarzScreen, ShellPairStore, SortedPairList};
use khf::linalg::Matrix;
use khf::runtime::{Runtime, XlaFockBuilder};
use khf::util::timer;

fn main() {
    khf::util::logging::init();
    let rt_dir = Runtime::default_dir();
    if !rt_dir.join("fock2e_8.hlo.txt").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return;
    }

    let mut rows = vec![vec![
        "molecule".into(),
        "BFs (padded)".into(),
        "serial build".into(),
        "xla build".into(),
        "xla/serial".into(),
        "max |dG|".into(),
    ]];
    for mol in [molecules::h2(), molecules::water(), molecules::methane(), molecules::benzene()] {
        let basis = BasisSet::assemble(&mol, BasisName::Sto3g).unwrap();
        let store = ShellPairStore::build(&basis);
        let screen = SchwarzScreen::build_with_store(&basis, &store, 0.0);
        let pairs = SortedPairList::build(&screen, &store);
        let mut d = Matrix::identity(basis.n_bf);
        d.scale(0.4);
        let ctx = FockContext::new(&basis, &store, &screen, &pairs, &d);

        let mut serial = SerialFock::new();
        let st_serial = timer::bench(3, 30, 0.3, || {
            timer::black_box(serial.build_2e(&ctx));
        });
        let g_serial = serial.build_2e(&ctx);

        let rt = Runtime::cpu(&rt_dir).unwrap();
        let mut xla = XlaFockBuilder::new_with_store(rt, &basis, &store).unwrap();
        let st_xla = timer::bench(3, 30, 0.3, || {
            timer::black_box(xla.build_2e(&ctx));
        });
        let g_xla = xla.build_2e(&ctx);

        rows.push(vec![
            mol.name.clone(),
            format!("{} ({})", basis.n_bf, xla.n_pad()),
            khf::util::human_secs(st_serial.mean),
            khf::util::human_secs(st_xla.mean),
            format!("{:.2}x", st_xla.mean / st_serial.mean),
            format!("{:.2e}", g_serial.max_abs_diff(&g_xla)),
        ]);
    }
    println!("== XLA dense Fock path vs direct sparse engine ==\n");
    print!("{}", report::table(&rows));
    println!(
        "\nnote: the dense path recomputes nothing (ERI tensor cached across iterations),\n\
         so per-iteration it wins on small molecules; the direct engines exist because the\n\
         dense tensor is O(N^4) memory and dies beyond ~100 BFs."
    );
}

//! Microbenchmark: the three real (threaded) Fock-build engines vs the
//! serial reference on one host — correctness-bearing overhead
//! comparison on this 1-core sandbox (parallel *speedups* come from the
//! simulator benches; this one measures the engines' real coordination
//! overhead at equal work).
//!
//! Run: cargo bench --bench bench_fock_engines

use khf::basis::{BasisName, BasisSet};
use khf::chem::graphene;
use khf::coordinator::report;
use khf::hf::mpi_only::MpiOnlyFock;
use khf::hf::private_fock::PrivateFock;
use khf::hf::serial::SerialFock;
use khf::hf::shared_fock::SharedFock;
use khf::hf::{FockBuilder, FockContext};
use khf::integrals::{SchwarzScreen, ShellPairStore, SortedPairList};
use khf::linalg::Matrix;
use khf::util::timer;

fn main() {
    let mol = graphene::bilayer(4, "c8");
    let basis = BasisSet::assemble(&mol, BasisName::SixThirtyOneGd).unwrap();
    let store = ShellPairStore::build(&basis);
    let screen = SchwarzScreen::build_with_store(&basis, &store, 1e-10);
    let pairs = SortedPairList::build(&screen, &store);
    let d = Matrix::identity(basis.n_bf);
    let ctx = FockContext::new(&basis, &store, &screen, &pairs, &d);

    println!("== Fock-build engines on c8 bilayer / 6-31G(d) ({} BFs) ==\n", basis.n_bf);
    let mut rows = vec![vec![
        "engine".into(),
        "config".into(),
        "time".into(),
        "vs serial".into(),
    ]];

    let mut serial = SerialFock::new();
    let st_serial = timer::bench(1, 3, 0.1, || {
        timer::black_box(serial.build_2e(&ctx));
    });
    rows.push(vec![
        "serial".into(),
        "1".into(),
        khf::util::human_secs(st_serial.mean),
        "1.00x".into(),
    ]);

    let mut add = |name: &str, cfg: String, st: timer::BenchStats| {
        rows.push(vec![
            name.into(),
            cfg,
            khf::util::human_secs(st.mean),
            format!("{:.2}x", st.mean / st_serial.mean),
        ]);
    };

    for (r, t) in [(1usize, 2usize), (2, 2), (4, 2)] {
        let mut eng = MpiOnlyFock::new(r * t);
        let st = timer::bench(1, 3, 0.1, || {
            timer::black_box(eng.build_2e(&ctx));
        });
        add("mpi-only", format!("{} ranks", r * t), st);

        let mut eng = PrivateFock::new(r, t);
        let st = timer::bench(1, 3, 0.1, || {
            timer::black_box(eng.build_2e(&ctx));
        });
        add("private-fock", format!("{r}x{t}"), st);

        let mut eng = SharedFock::new(r, t);
        let st = timer::bench(1, 3, 0.1, || {
            timer::black_box(eng.build_2e(&ctx));
        });
        add("shared-fock", format!("{r}x{t}"), st);
    }
    print!("{}", report::table(&rows));
    println!("\n(1-core sandbox: oversubscribed threads; expect ~1x ± coordination overhead)");
}

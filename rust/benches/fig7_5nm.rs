//! Paper Figure 7 — shared-Fock scalability on the 5.0 nm system
//! (30,240 basis functions) from 500 to 3,000 Theta nodes / 192,000
//! cores (simulated).
//!
//! Run: cargo bench --bench fig7_5nm   (several minutes: the workload
//! statistics compute real Schwarz bounds over 32.5M shell pairs)

use khf::chem::graphene::PaperSystem;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system};
use khf::hf::memmodel::EngineKind;

fn main() {
    khf::util::logging::init();
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(PaperSystem::Nm50, &cost).expect("stats");

    println!("== Fig 7: shared-Fock scaling, 5.0 nm, 4 ranks x 64 threads/node ==\n");
    let nodes = [500usize, 1000, 1500, 2000, 2500, 3000];
    let mut rows = vec![vec![
        "nodes".into(),
        "cores".into(),
        "Fock t(s) x15".into(),
        "speedup".into(),
        "ideal".into(),
        "GB/node".into(),
    ]];
    let mut base: Option<f64> = None;
    for &n in &nodes {
        let shf = simulate(EngineKind::SharedFock, &stats, &Machine::theta_hybrid(n), &cost);
        let b = *base.get_or_insert(shf.fock_seconds);
        rows.push(vec![
            n.to_string(),
            (n * 64).to_string(),
            report::secs(shf.fock_seconds * 15.0),
            format!("{:.2}", b / shf.fock_seconds),
            format!("{:.2}", n as f64 / nodes[0] as f64),
            format!("{:.0}", shf.bytes_per_node / 1e9),
        ]);
    }
    print!("{}", report::table(&rows));
    println!(
        "\npaper shape: good scaling to 3,000 nodes / 192,000 cores; footprint ~208 GB/node\n\
         (the only engine that fits this system on Theta at all)."
    );
}

//! Paper Table 3 — time-to-solution and parallel efficiency of the
//! three codes on the 2.0 nm system, 4–512 Theta nodes (simulated; see
//! DESIGN.md §2 for the substitution audit).
//!
//! Run: cargo bench --bench table3_multinode
//! Env: KHF_SYSTEM=0.5|1.0|1.5|2.0|5.0 (default 2.0),
//!      KHF_FAST=1 uses the fallback cost model without recalibration.

use khf::chem::graphene::PaperSystem;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system};
use khf::hf::memmodel::EngineKind;

const N_ITER: f64 = 15.0; // SCF iterations folded into time-to-solution

fn main() {
    khf::util::logging::init();
    let sys = std::env::var("KHF_SYSTEM")
        .ok()
        .and_then(|s| PaperSystem::parse(&s))
        .unwrap_or(PaperSystem::Nm20);
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(sys, &cost).expect("workload stats");

    // Paper Table 3 for 2.0 nm (s / parallel efficiency %).
    let paper: [(usize, f64, f64, f64); 6] = [
        (4, 2661.0, 1128.0, 1318.0),
        (16, 685.0, 288.0, 332.0),
        (64, 195.0, 78.0, 85.0),
        (128, 118.0, 49.0, 43.0),
        (256, 85.0, 44.0, 23.0),
        (512, 82.0, 44.0, 13.0),
    ];

    let nodes: Vec<usize> = paper.iter().map(|p| p.0).collect();
    let mut results = Vec::new();
    for &n in &nodes {
        let mpi = simulate(EngineKind::MpiOnly, &stats, &Machine::theta_mpi(n), &cost);
        let prf = simulate(EngineKind::PrivateFock, &stats, &Machine::theta_hybrid(n), &cost);
        let shf = simulate(EngineKind::SharedFock, &stats, &Machine::theta_hybrid(n), &cost);
        results.push((n, mpi, prf, shf));
    }

    let base = &results[0];
    let eff = |t0: f64, t: f64, n0: usize, n: usize| {
        report::pct((t0 * n0 as f64) / (t * n as f64))
    };

    println!(
        "== Table 3: {} time-to-solution (s, {N_ITER} SCF iterations) + parallel efficiency ==\n",
        stats.label
    );
    let mut rows = vec![vec![
        "nodes".into(),
        "MPI sim".into(),
        "MPI paper".into(),
        "PrF sim".into(),
        "PrF paper".into(),
        "ShF sim".into(),
        "ShF paper".into(),
        "eff MPI%".into(),
        "eff PrF%".into(),
        "eff ShF%".into(),
        "paper eff".into(),
    ]];
    let paper_eff = ["100/100/100", "97/98/99", "85/90/97", "70/72/96", "49/40/90", "25/20/79"];
    for (k, (n, mpi, prf, shf)) in results.iter().enumerate() {
        rows.push(vec![
            n.to_string(),
            report::secs(mpi.fock_seconds * N_ITER),
            format!("{}", paper[k].1),
            report::secs(prf.fock_seconds * N_ITER),
            format!("{}", paper[k].2),
            report::secs(shf.fock_seconds * N_ITER),
            format!("{}", paper[k].3),
            eff(base.1.fock_seconds, mpi.fock_seconds, base.0, *n),
            eff(base.2.fock_seconds, prf.fock_seconds, base.0, *n),
            eff(base.3.fock_seconds, shf.fock_seconds, base.0, *n),
            paper_eff[k].into(),
        ]);
    }
    print!("{}", report::table(&rows));

    let last = results.last().unwrap();
    println!(
        "\nheadline: shared-Fock vs MPI-only at {} nodes = {:.1}x (paper: ~6x)",
        last.0,
        last.1.fock_seconds / last.3.fock_seconds
    );
    println!(
        "MPI-only ranks/node after memory gate: {} (replicated footprint {:.0} GB)",
        last.1.ranks_per_node_used,
        last.1.bytes_per_node / 1e9
    );
}

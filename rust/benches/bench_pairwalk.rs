//! Benchmark: sorted early-exit pair walks vs the legacy
//! enumerate-and-test screening, per SCF iteration.
//!
//! The legacy scheme visits every canonical quartet ordinal and calls
//! `screened_weighted` on each — O(N⁴) loop-and-branch work even when
//! ΔD has collapsed and almost nothing survives. The sorted walk makes
//! the bound a *loop limit*: visited = computed, and the dead quartet
//! space is never enumerated. This bench drives a real incremental SCF
//! with a probing builder that, for every build, counts both schemes on
//! the same density, then times the two enumeration strategies in
//! isolation on the converged ΔD.
//!
//! Run: cargo bench --bench bench_pairwalk
//! (Numbers land in EXPERIMENTS.md §2.)

use std::time::Instant;

use khf::basis::BasisName;
use khf::chem::{molecules, Molecule};
use khf::coordinator::report;
use khf::hf::quartets::{for_each_canonical, n_canonical};
use khf::hf::serial::SerialFock;
use khf::hf::{BuildStats, FockBuilder, FockContext};
use khf::linalg::Matrix;
use khf::scf::RhfDriver;
use khf::util::timer;

/// Per-build comparison row captured inside the SCF loop.
struct ProbeRow {
    /// Canonical quartets the legacy scheme enumerates (and tests).
    legacy_visited: u64,
    /// Quartets surviving the legacy per-quartet weighted test.
    legacy_survivors: u64,
    /// Quartets the sorted walk enumerates (= computes).
    early_visited: u64,
}

/// A serial builder that counts both screening schemes per build.
struct PairwalkProbe {
    inner: SerialFock,
    rows: Vec<ProbeRow>,
}

impl PairwalkProbe {
    fn new() -> Self {
        PairwalkProbe { inner: SerialFock::new(), rows: Vec::new() }
    }
}

impl FockBuilder for PairwalkProbe {
    fn build_2e(&mut self, ctx: &FockContext) -> Matrix {
        let nsh = ctx.basis.n_shells();
        // Legacy baseline: enumerate-and-test over the whole space.
        let mut survivors = 0u64;
        for_each_canonical(nsh, |(i, j, k, l)| {
            if !ctx.screened(i, j, k, l) {
                survivors += 1;
            }
        });
        self.rows.push(ProbeRow {
            legacy_visited: n_canonical(nsh),
            legacy_survivors: survivors,
            early_visited: ctx.walk.n_visited(),
        });
        self.inner.build_2e(ctx)
    }

    fn name(&self) -> &'static str {
        "pairwalk-probe"
    }

    fn last_stats(&self) -> BuildStats {
        self.inner.last_stats()
    }
}

fn run_case(mol: &Molecule, basis: BasisName, expect_final_win: bool) {
    let driver = RhfDriver { rebuild_every: 0, ..Default::default() };
    let mut probe = PairwalkProbe::new();
    let t0 = Instant::now();
    let res = driver.run(mol, basis, &mut probe).expect("scf");
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "-- {} / {}: E = {:.8} Ha, {} iterations, converged={}, {} listed pairs",
        mol.name,
        basis.label(),
        res.energy,
        res.iterations,
        res.converged,
        res.pairs_listed,
    );
    let mut rows = vec![vec![
        "iter".into(),
        "legacy visited".into(),
        "legacy survivors".into(),
        "early-exit visited".into(),
        "visit reduction".into(),
    ]];
    for (it, r) in probe.rows.iter().enumerate() {
        rows.push(vec![
            (it + 1).to_string(),
            r.legacy_visited.to_string(),
            r.legacy_survivors.to_string(),
            r.early_visited.to_string(),
            format!("{:.1}x", r.legacy_visited as f64 / (r.early_visited.max(1)) as f64),
        ]);
    }
    print!("{}", report::table(&rows));

    let last = probe.rows.last().expect("at least one build");
    println!(
        "   final ΔD iteration: legacy enumerates {} quartets to keep {}, \
         early exit visits {} ({}x fewer loop iterations); wall {}\n",
        last.legacy_visited,
        last.legacy_survivors,
        last.early_visited,
        (last.legacy_visited / last.early_visited.max(1)),
        khf::util::human_secs(wall),
    );
    // Compact few-shell systems can keep every Q product above τ/w even
    // at convergence (no pairs to exit over); the headline claim is for
    // systems with a broad Schwarz spread, so only those hard-assert.
    if expect_final_win {
        assert!(
            last.early_visited < last.legacy_visited,
            "early exit must beat enumerate-and-test on the final ΔD iteration"
        );
    }
}

/// Time the two enumeration strategies alone (no ERIs): the loop/branch
/// overhead the sorted walk removes from every late iteration.
fn time_enumeration(mol: &Molecule, basis_name: BasisName) {
    use khf::basis::BasisSet;
    use khf::integrals::{SchwarzScreen, ShellPairStore, SortedPairList};

    let basis = BasisSet::assemble(mol, basis_name).unwrap();
    let store = ShellPairStore::build(&basis);
    let screen = SchwarzScreen::build_with_store(&basis, &store, SchwarzScreen::DEFAULT_TAU);
    let pairs = SortedPairList::build(&screen, &store);
    // A converged-magnitude ΔD: uniform 1e-9 — late-iteration regime.
    let n = basis.n_bf;
    let mut delta = Matrix::identity(n);
    delta.scale(1e-9);
    let ctx = FockContext::new(&basis, &store, &screen, &pairs, &delta);

    let st_legacy = timer::bench(3, 20, 0.3, || {
        let mut kept = 0u64;
        for_each_canonical(basis.n_shells(), |(i, j, k, l)| {
            if !ctx.screened(i, j, k, l) {
                kept += 1;
            }
        });
        timer::black_box(&kept);
    });
    let st_walk = timer::bench(3, 20, 0.3, || {
        let mut kept = 0u64;
        for t in 0..ctx.walk.n_tasks() {
            let rij = ctx.walk.task(t);
            kept += ctx.walk.kl_limit(rij) as u64;
        }
        timer::black_box(&kept);
    });
    println!(
        "enumeration overhead on {} (1e-9 ΔD): legacy {} vs sorted walk {} ({:.0}x)",
        mol.name,
        st_legacy,
        st_walk,
        st_legacy.mean / st_walk.mean.max(1e-12),
    );
}

fn main() {
    println!("== Sorted early-exit walks vs enumerate-and-test screening ==\n");
    for (mol, basis, expect_final_win) in [
        (molecules::benzene(), BasisName::Sto3g, true),
        (molecules::methane(), BasisName::SixThirtyOneG, false),
    ] {
        run_case(&mol, basis, expect_final_win);
    }
    time_enumeration(&molecules::benzene(), BasisName::Sto3g);
    println!(
        "\nnote: 'early-exit visited' equals quartets computed (the walk never tests\n\
         quartets individually); the legacy column pays a screened_weighted call per\n\
         canonical quartet every iteration regardless of how little survives."
    );
}

//! Benchmark: two-key sorted early-exit pair walks vs the PR 2
//! single-key (global-weight) walk vs the legacy enumerate-and-test
//! screening, per SCF iteration.
//!
//! The legacy scheme visits every canonical quartet ordinal and calls
//! `screened_weighted` on each — O(N⁴) loop-and-branch work even when
//! ΔD has collapsed and almost nothing survives. The sorted walks make
//! the bound a *loop limit*; the two-key walk additionally folds
//! per-pair row-max density weights in, computing exactly the
//! factorized weighted survivor set (strictly fewer quartets than the
//! global-weight walk whenever the density's block structure is
//! uneven). This bench drives a real incremental SCF with a probing
//! builder that, for every build, counts all three schemes on the same
//! density, then times the enumeration strategies in isolation on the
//! converged ΔD.
//!
//! Run: cargo bench --bench bench_pairwalk
//! (Numbers land in EXPERIMENTS.md §2.)

use std::time::Instant;

use khf::basis::BasisName;
use khf::chem::{molecules, Molecule};
use khf::coordinator::report;
use khf::hf::quartets::{for_each_canonical, n_canonical};
use khf::hf::serial::SerialFock;
use khf::hf::{BuildStats, FockBuilder, FockContext};
use khf::linalg::Matrix;
use khf::scf::RhfDriver;
use khf::util::timer;

/// Per-build comparison row captured inside the SCF loop.
struct ProbeRow {
    /// Canonical quartets the legacy scheme enumerates (and tests).
    legacy_visited: u64,
    /// Quartets surviving the legacy per-quartet weighted test.
    legacy_survivors: u64,
    /// Quartets the PR 2 single-key walk (global weight max|D|) would
    /// compute on this density.
    global_visited: u64,
    /// Quartets the two-key walk computes (= the exact factorized
    /// weighted survivor set).
    two_key_visited: u64,
    /// Two-key iteration ordinals enumerated (computed + rejected
    /// segment-B candidates, each one integer compare).
    two_key_candidates: u64,
}

/// A serial builder that counts all screening schemes per build.
struct PairwalkProbe {
    inner: SerialFock,
    rows: Vec<ProbeRow>,
}

impl PairwalkProbe {
    fn new() -> Self {
        PairwalkProbe { inner: SerialFock::new(), rows: Vec::new() }
    }
}

impl FockBuilder for PairwalkProbe {
    fn build_2e(&mut self, ctx: &FockContext) -> Matrix {
        let nsh = ctx.basis.n_shells();
        // Legacy baseline: enumerate-and-test over the whole space.
        let mut survivors = 0u64;
        for_each_canonical(nsh, |(i, j, k, l)| {
            if !ctx.screened(i, j, k, l) {
                survivors += 1;
            }
        });
        self.rows.push(ProbeRow {
            legacy_visited: n_canonical(nsh),
            legacy_survivors: survivors,
            global_visited: ctx.pairs.n_visited_at(ctx.dmax.global),
            two_key_visited: ctx.walk.n_visited(),
            two_key_candidates: ctx.walk.n_candidates(),
        });
        self.inner.build_2e(ctx)
    }

    fn name(&self) -> &'static str {
        "pairwalk-probe"
    }

    fn last_stats(&self) -> BuildStats {
        self.inner.last_stats()
    }
}

fn run_case(mol: &Molecule, basis: BasisName, expect_final_win: bool) {
    let driver = RhfDriver { rebuild_every: 0, ..Default::default() };
    let mut probe = PairwalkProbe::new();
    let t0 = Instant::now();
    let res = driver.run(mol, basis, &mut probe).expect("scf");
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "-- {} / {}: E = {:.8} Ha, {} iterations, converged={}, {} listed pairs",
        mol.name,
        basis.label(),
        res.energy,
        res.iterations,
        res.converged,
        res.pairs_listed,
    );
    let mut rows = vec![vec![
        "iter".into(),
        "legacy visited".into(),
        "legacy survivors".into(),
        "global-w visited".into(),
        "two-key visited".into(),
        "two-key candidates".into(),
        "two-key gain".into(),
    ]];
    for (it, r) in probe.rows.iter().enumerate() {
        rows.push(vec![
            (it + 1).to_string(),
            r.legacy_visited.to_string(),
            r.legacy_survivors.to_string(),
            r.global_visited.to_string(),
            r.two_key_visited.to_string(),
            r.two_key_candidates.to_string(),
            format!(
                "{:.2}x",
                r.global_visited as f64 / (r.two_key_visited.max(1)) as f64
            ),
        ]);
    }
    print!("{}", report::table(&rows));

    let last = probe.rows.last().expect("at least one build");
    println!(
        "   final ΔD iteration: legacy enumerates {} quartets to keep {}, \
         global-weight walk computes {}, two-key walk computes {} \
         ({} candidates); wall {}\n",
        last.legacy_visited,
        last.legacy_survivors,
        last.global_visited,
        last.two_key_visited,
        last.two_key_candidates,
        khf::util::human_secs(wall),
    );
    // Structural invariants of the two-key walk, on every build: it
    // nests inside the PR 2 global-weight walk and keeps every legacy
    // per-quartet Häser–Ahlrichs survivor.
    let mut sum_global = 0u64;
    let mut sum_two_key = 0u64;
    for r in &probe.rows {
        assert!(r.two_key_visited <= r.global_visited, "two-key must nest");
        assert!(r.two_key_visited >= r.legacy_survivors, "lost HA survivors");
        assert!(r.two_key_candidates >= r.two_key_visited);
        sum_global += r.global_visited;
        sum_two_key += r.two_key_visited;
    }
    // Compact few-shell systems can keep every Q product above τ/w even
    // at convergence (no pairs to exit over); the headline claims are
    // for systems with a broad Schwarz spread, so only those
    // hard-assert.
    if expect_final_win {
        assert!(
            last.two_key_visited < last.legacy_visited,
            "early exit must beat enumerate-and-test on the final ΔD iteration"
        );
        assert!(
            sum_two_key < sum_global,
            "two-key walk must compute strictly fewer quartets over the run \
             ({sum_two_key} vs global {sum_global})"
        );
    }
}

/// Time the two enumeration strategies alone (no ERIs): the loop/branch
/// overhead the sorted walk removes from every late iteration.
fn time_enumeration(mol: &Molecule, basis_name: BasisName) {
    use khf::basis::BasisSet;
    use khf::integrals::{SchwarzScreen, ShellPairStore, SortedPairList};

    let basis = BasisSet::assemble(mol, basis_name).unwrap();
    let store = ShellPairStore::build(&basis);
    let screen = SchwarzScreen::build_with_store(&basis, &store, SchwarzScreen::DEFAULT_TAU);
    let pairs = SortedPairList::build(&screen, &store);
    // A converged-magnitude ΔD: uniform 1e-9 — late-iteration regime.
    let n = basis.n_bf;
    let mut delta = Matrix::identity(n);
    delta.scale(1e-9);
    let ctx = FockContext::new(&basis, &store, &screen, &pairs, &delta);

    let st_legacy = timer::bench(3, 20, 0.3, || {
        let mut kept = 0u64;
        for_each_canonical(basis.n_shells(), |(i, j, k, l)| {
            if !ctx.screened(i, j, k, l) {
                kept += 1;
            }
        });
        timer::black_box(&kept);
    });
    let st_walk = timer::bench(3, 20, 0.3, || {
        let mut kept = 0u64;
        for t in 0..ctx.walk.n_tasks() {
            let rij = ctx.walk.task(t);
            // Full two-key enumeration including the segment-B
            // candidate rejections — what an engine actually pays.
            kept += ctx.walk.kets(rij).iter().count() as u64;
        }
        timer::black_box(&kept);
    });
    println!(
        "enumeration overhead on {} (1e-9 ΔD): legacy {} vs two-key walk {} ({:.0}x)",
        mol.name,
        st_legacy,
        st_walk,
        st_legacy.mean / st_walk.mean.max(1e-12),
    );
}

fn main() {
    println!("== Sorted early-exit walks vs enumerate-and-test screening ==\n");
    for (mol, basis, expect_final_win) in [
        (molecules::benzene(), BasisName::Sto3g, true),
        (molecules::methane(), BasisName::SixThirtyOneG, false),
    ] {
        run_case(&mol, basis, expect_final_win);
    }
    time_enumeration(&molecules::benzene(), BasisName::Sto3g);
    println!(
        "\nnote: 'two-key visited' equals quartets computed — exactly the survivors of\n\
         Q_ij·Q_kl·max(w_ij,w_kl) > tau, never more; 'two-key candidates' adds the\n\
         segment-B rejections (one integer compare each, no bound evaluation). The\n\
         'global-w visited' column is the PR 2 single-key walk; the legacy column\n\
         pays a screened_weighted call per canonical quartet every iteration\n\
         regardless of how little survives."
    );
}

//! Benchmark: the multi-tenant SCF service over a seeded mixed
//! workload.
//!
//! A 60-job stream (mixed molecules, bases, engines and store layouts,
//! all drawn from a seeded generator) is admitted, gated on per-node
//! memory, packed onto a small virtual cluster, and costed per job on
//! the discrete-event core. The interesting service-level quantities —
//! throughput, latency percentiles, profile-cache hit rate, per-node
//! packing — land in BENCH_service.json; the structural claims (cache
//! hits happen, the gate is never violated, the report is
//! deterministic) are asserted here from the schedule itself, never
//! from hardcoded numbers.
//!
//! Run: cargo bench --bench bench_service
//! (Numbers land in EXPERIMENTS.md §10; rows in BENCH_service.json.)

use khf::cluster::CostModel;
use khf::coordinator::{run_service, ServiceConfig, WorkloadSpec};

fn main() {
    println!("== Multi-tenant SCF service: seeded 60-job mixed workload ==\n");
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    // A deliberately tight per-node gate (2 GB) so the packer has to
    // queue and spill across nodes — with the full 208 GB every tiny
    // job would run at arrival and the latency tail would be flat.
    let cfg = ServiceConfig {
        nodes: 4,
        node_bytes: 2e9,
        seed: 7,
        ..ServiceConfig::default()
    };
    let jobs = WorkloadSpec { n_jobs: 60, seed: cfg.seed }.generate();
    let report = run_service(&jobs, &cfg, &cost).expect("service run");
    print!("{}", report.render());

    // Structural invariants of the service claims.
    assert!(report.cache_hits >= 1, "60 jobs over a ~10-profile pool must hit the cache");
    assert!(
        report.cache_entries < report.submitted,
        "profiles must be shared across jobs"
    );
    assert!(report.p50 > 0.0 && report.p50 <= report.p95 && report.p95 <= report.p99);
    assert!(report.throughput > 0.0);
    // The admission gate audited from the packing trace, not trusted:
    // every placement fits its node, every peak fits the capacity.
    for p in &report.placements {
        assert!(p.bytes <= cfg.node_bytes, "job {} over the gate", p.id);
        assert!(p.node < cfg.nodes);
    }
    for (n, &peak) in report.node_peak_bytes.iter().enumerate() {
        assert!(peak <= cfg.node_bytes, "node {n} peak {peak} over the gate");
    }
    // Determinism: a second run with identical inputs is byte-identical.
    let again = run_service(&jobs, &cfg, &cost).expect("service rerun");
    assert_eq!(report.render(), again.render(), "replay must be byte-identical");

    println!(
        "\nnote: service times are DES outputs of the calibrated per-engine cost\n\
         model (one virtual node per job), not silicon measurements; latency is\n\
         queueing + service under the LPT/first-fit packer. The cache-hit rate\n\
         rises with stream length at fixed pool size, and a tighter --node-gb\n\
         gate trades throughput for a longer latency tail."
    );
    report.bench_json().write();
}

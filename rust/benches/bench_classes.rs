//! Class-batched quartet pipeline — population histograms and the
//! scalar-vs-batched drain measurement (the `EriEngine` scratch-reuse
//! win: one bra resolution per same-bra run instead of one per
//! quartet). Emits BENCH_classes.json.
//!
//! Run: cargo bench --bench bench_classes

use std::time::Instant;

use khf::basis::{BasisName, BasisSet};
use khf::chem::molecules;
use khf::coordinator::{report, BenchJson};
use khf::hf::hetero_fock::HeteroFock;
use khf::hf::quartets::for_each_surviving;
use khf::hf::{FockBuilder, FockContext};
use khf::integrals::{
    EriEngine, QuartetSite, SchwarzScreen, ShellPairStore, SortedPairList,
};
use khf::linalg::Matrix;
use khf::scf::RhfDriver;

fn main() {
    khf::util::logging::init();
    let mut json = BenchJson::new("classes");

    // == 1. Pair- and quartet-class populations ==
    // The split policy's input: listed-pair counts per angular-momentum
    // class, and the quartet-class histogram an actual build records.
    println!("== Class populations (Q-sorted surviving pairs) ==\n");
    for (mol, basis_name) in [
        (molecules::water(), BasisName::Sto3g),
        (molecules::benzene(), BasisName::Sto3g),
        (molecules::benzene(), BasisName::SixThirtyOneG),
    ] {
        let basis = BasisSet::assemble(&mol, basis_name).expect("basis");
        let store = ShellPairStore::build(&basis);
        let screen =
            SchwarzScreen::build_with_store(&basis, &store, SchwarzScreen::DEFAULT_TAU);
        let pairs = SortedPairList::build(&screen, &store);
        let config = format!("{}/{}", mol.name, basis_name.label());
        let m = pairs.n_pair_classes();
        let counts = pairs.class_counts();
        let mut rows = vec![vec!["pair class".into(), "listed pairs".into()]];
        for c in 0..m {
            let (ka, kb) = pairs.class_kinds(c);
            let label = format!("{ka:?}{kb:?}");
            json.row(&config, &format!("pairs_class_{label}"), counts[c] as f64);
            rows.push(vec![label, counts[c].to_string()]);
        }
        println!("{config}: {} pairs in {m} classes", pairs.len());
        print!("{}", report::table(&rows));

        // Quartet histogram from a real build (the drain counters).
        let d = Matrix::identity(basis.n_bf);
        let ctx = FockContext::new(&basis, &store, &screen, &pairs, &d);
        let mut eng = khf::hf::serial::SerialFock::new();
        let _ = eng.build_2e(&ctx);
        let stats = eng.last_stats();
        let total: u64 = stats.class_quartets.iter().sum();
        for (c, &q) in stats.class_quartets.iter().enumerate() {
            if q > 0 {
                let (ba, bb) = pairs.class_kinds(c / m);
                let (ka, kb) = pairs.class_kinds(c % m);
                json.row(
                    &config,
                    &format!("quartets_class_{ba:?}{bb:?}_{ka:?}{kb:?}"),
                    q as f64,
                );
            }
        }
        println!(
            "quartets: {total} computed, {}/{} classes populated\n",
            stats.class_quartets.iter().filter(|&&q| q > 0).count(),
            stats.class_quartets.len(),
        );
    }

    // == 2. Scalar vs batched drain (the satellite fix's measurement) ==
    // Same surviving quartet set, same engine math; the batched path
    // pays one scratch setup per run and one bra resolution per
    // distinct bra instead of one per quartet.
    println!("== Scalar vs batched ERI drain (benzene/STO-3G) ==\n");
    let mol = molecules::benzene();
    let basis = BasisSet::assemble(&mol, BasisName::Sto3g).expect("basis");
    let store = ShellPairStore::build(&basis);
    let screen = SchwarzScreen::build_with_store(&basis, &store, SchwarzScreen::DEFAULT_TAU);
    let pairs = SortedPairList::build(&screen, &store);
    let d = Matrix::identity(basis.n_bf);
    let ctx = FockContext::new(&basis, &store, &screen, &pairs, &d);
    let m = pairs.n_pair_classes();
    // Bucket the full surviving set by quartet class, walk order kept
    // inside each bucket (so batched runs see same-bra site runs).
    let mut by_class: Vec<Vec<QuartetSite>> = vec![Vec::new(); m * m];
    for_each_surviving(&ctx.walk, |rij, rkl| {
        let c = khf::integrals::quartet_class(&pairs, rij, rkl);
        let bra = pairs.entry(rij);
        let ket = pairs.entry(rkl);
        by_class[c].push(QuartetSite {
            i: bra.i,
            j: bra.j,
            k: ket.i,
            l: ket.j,
            bra_slot: bra.slot,
            ket_slot: ket.slot,
        });
    });
    let n_quartets: usize = by_class.iter().map(|v| v.len()).sum();

    let reps = 3;
    let mut scalar_best = f64::INFINITY;
    let mut scalar_resolves = 0u64;
    let mut sink_scalar = 0.0f64;
    for _ in 0..reps {
        let mut eng = EriEngine::new();
        let mut block = vec![0.0; 6 * 6 * 6 * 6];
        let t0 = Instant::now();
        for sites in &by_class {
            for s in sites {
                eng.shell_quartet_slots(
                    &basis,
                    &store,
                    s.i as usize,
                    s.j as usize,
                    s.k as usize,
                    s.l as usize,
                    s.bra_slot,
                    s.ket_slot,
                    &mut block,
                );
                sink_scalar += block[0];
            }
        }
        scalar_best = scalar_best.min(t0.elapsed().as_secs_f64());
        scalar_resolves = eng.bra_resolves;
    }

    let batch_size = khf::hf::DEFAULT_BATCH_SIZE;
    let mut batched_best = f64::INFINITY;
    let mut batched_resolves = 0u64;
    let mut sink_batched = 0.0f64;
    for _ in 0..reps {
        let mut eng = EriEngine::new();
        let t0 = Instant::now();
        for sites in &by_class {
            for chunk in sites.chunks(batch_size) {
                eng.shell_quartet_batch(
                    &basis,
                    |slot, swap| store.view_by_slot(slot, swap),
                    chunk,
                    |_, block| sink_batched += block[0],
                );
            }
        }
        batched_best = batched_best.min(t0.elapsed().as_secs_f64());
        batched_resolves = eng.bra_resolves;
    }
    std::hint::black_box((sink_scalar, sink_batched));
    println!(
        "{n_quartets} quartets: scalar {:.1} ms ({scalar_resolves} bra resolves) vs \
         batched {:.1} ms ({batched_resolves} bra resolves, batch {batch_size}) — \
         {:.2}x, {:.1}x fewer resolves",
        1e3 * scalar_best,
        1e3 * batched_best,
        scalar_best / batched_best,
        scalar_resolves as f64 / batched_resolves.max(1) as f64,
    );
    json.row("benzene/STO-3G", "scalar_drain_seconds", scalar_best);
    json.row("benzene/STO-3G", "batched_drain_seconds", batched_best);
    json.row("benzene/STO-3G", "scalar_bra_resolves", scalar_resolves as f64);
    json.row("benzene/STO-3G", "batched_bra_resolves", batched_resolves as f64);
    json.row("benzene/STO-3G", "drain_quartets", n_quartets as f64);

    // == 3. Heterogeneous engine end-to-end ==
    // Full SCF through the class-split engine (host fallback when no
    // blockjk artifact is installed) — the flush accounting and the
    // populous/tail split at the default policy.
    println!("\n== hetero engine SCF (benzene/STO-3G, 1 rank x 4 threads) ==\n");
    let mut hetero = HeteroFock::new(1, 4);
    let t0 = Instant::now();
    let res = RhfDriver::default()
        .run(&mol, BasisName::Sto3g, &mut hetero)
        .expect("hetero scf");
    let wall = t0.elapsed().as_secs_f64();
    let first = res.build_stats.first().expect("stats");
    println!(
        "E = {:.8} Ha, converged={} in {} iterations ({:.2} s; Fock {:.2} s)\n\
         first build: {} batches x {batch_size} + {} tail of {} quartets, \
         {} accel batches",
        res.energy,
        res.converged,
        res.iterations,
        wall,
        res.fock_build_seconds,
        first.batches_flushed,
        first.tail_quartets,
        first.quartets_computed,
        first.accel_batches,
    );
    json.row("benzene/STO-3G", "hetero_fock_seconds", res.fock_build_seconds);
    json.row("benzene/STO-3G", "hetero_batches_flushed", first.batches_flushed as f64);
    json.row("benzene/STO-3G", "hetero_tail_quartets", first.tail_quartets as f64);
    json.row("benzene/STO-3G", "hetero_accel_batches", first.accel_batches as f64);

    json.write();
}

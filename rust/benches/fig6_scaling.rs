//! Paper Figure 6 — multi-node scalability (log-log speedup) of the
//! three codes on the 2.0 nm system, 4–512 nodes (simulated Theta).
//!
//! Run: cargo bench --bench fig6_scaling

use khf::chem::graphene::PaperSystem;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system, BenchJson};
use khf::hf::memmodel::EngineKind;

fn main() {
    khf::util::logging::init();
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(PaperSystem::Nm20, &cost).expect("stats");
    let mut json = BenchJson::new("fig6_scaling");

    println!("== Fig 6: multi-node speedup, 2.0 nm (relative to 4 nodes) ==\n");
    let nodes = [4usize, 8, 16, 32, 64, 128, 256, 512];
    let mut base: Option<(f64, f64, f64)> = None;
    let mut rows = vec![vec![
        "nodes".into(),
        "MPI t(s)".into(),
        "MPI speedup".into(),
        "PrF t(s)".into(),
        "PrF speedup".into(),
        "ShF t(s)".into(),
        "ShF speedup".into(),
        "ideal".into(),
    ]];
    for &n in &nodes {
        let mpi = simulate(EngineKind::MpiOnly, &stats, &Machine::theta_mpi(n), &cost);
        let prf = simulate(EngineKind::PrivateFock, &stats, &Machine::theta_hybrid(n), &cost);
        let shf = simulate(EngineKind::SharedFock, &stats, &Machine::theta_hybrid(n), &cost);
        let b = *base.get_or_insert((mpi.fock_seconds, prf.fock_seconds, shf.fock_seconds));
        let config = format!("2.0nm/{n}nodes");
        json.row(&config, "mpi_fock_seconds", mpi.fock_seconds);
        json.row(&config, "mpi_speedup", b.0 / mpi.fock_seconds);
        json.row(&config, "private_fock_seconds", prf.fock_seconds);
        json.row(&config, "private_speedup", b.1 / prf.fock_seconds);
        json.row(&config, "shared_fock_seconds", shf.fock_seconds);
        json.row(&config, "shared_speedup", b.2 / shf.fock_seconds);
        rows.push(vec![
            n.to_string(),
            report::secs(mpi.fock_seconds * 15.0),
            format!("{:.1}", b.0 / mpi.fock_seconds),
            report::secs(prf.fock_seconds * 15.0),
            format!("{:.1}", b.1 / prf.fock_seconds),
            report::secs(shf.fock_seconds * 15.0),
            format!("{:.1}", b.2 / shf.fock_seconds),
            format!("{:.0}", n as f64 / nodes[0] as f64),
        ]);
    }
    print!("{}", report::table(&rows));
    println!(
        "\npaper shape: shared Fock tracks ideal furthest (finest ij x kl balance);\n\
         private Fock saturates first (only NShells i-tasks for the rank-level DLB);\n\
         MPI-only in between but slowest in absolute time."
    );
    json.write();
}

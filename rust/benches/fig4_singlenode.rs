//! Paper Figure 4 — single-node scalability vs hardware threads of the
//! three codes on the 1.0 nm system (simulated KNL node; MPI-only is
//! gated by the MCDRAM footprint exactly as in the paper).
//!
//! Run: cargo bench --bench fig4_singlenode

use khf::chem::graphene::PaperSystem;
use khf::cluster::knl::Affinity;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system};
use khf::hf::memmodel::EngineKind;

fn main() {
    khf::util::logging::init();
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(PaperSystem::Nm10, &cost).expect("stats");

    println!("== Fig 4: single-node scaling vs hardware threads (1.0 nm) ==");
    println!("   time = one Fock build (s); '-' = does not fit MCDRAM\n");
    let mut rows = vec![vec![
        "hw threads".into(),
        "MPI-only".into(),
        "Private Fock".into(),
        "Shared Fock".into(),
    ]];
    for hw in [4usize, 8, 16, 32, 64, 128, 256] {
        // Hybrids: 4 ranks x (hw/4) threads (paper's single-node setup);
        // below 4 hw threads fall back to 1 rank.
        let ranks = if hw >= 4 { 4 } else { 1 };
        let hybrid = Machine {
            nodes: 1,
            ranks_per_node: ranks,
            threads_per_rank: hw / ranks,
            mcdram_only: true,
            affinity: Affinity::Balanced,
            ..Machine::theta_hybrid(1)
        };
        // MPI-only: hw single-thread ranks.
        let mpi_m = Machine {
            nodes: 1,
            ranks_per_node: hw,
            threads_per_rank: 1,
            mcdram_only: true,
            ..Machine::theta_mpi(1)
        };
        let mpi = simulate(EngineKind::MpiOnly, &stats, &mpi_m, &cost);
        let prf = simulate(EngineKind::PrivateFock, &stats, &hybrid, &cost);
        let shf = simulate(EngineKind::SharedFock, &stats, &hybrid, &cost);
        let mpi_cell = if mpi.feasible && mpi.ranks_per_node_used == hw {
            report::secs(mpi.fock_seconds)
        } else {
            format!("- ({} ranks fit)", mpi.ranks_per_node_used)
        };
        rows.push(vec![
            hw.to_string(),
            mpi_cell,
            report::secs(prf.fock_seconds),
            report::secs(shf.fock_seconds),
        ]);
    }
    print!("{}", report::table(&rows));
    println!(
        "\npaper shape: private Fock best at every thread count; MPI-only capped at 128\n\
         hardware threads by the replicated MCDRAM footprint; hybrids reach all 256."
    );
}

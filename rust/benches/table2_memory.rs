//! Paper Table 2 — memory footprints of the three codes on the five
//! graphene systems: eqs. (3a)–(3c) alongside the exact allocation
//! accounting, with the paper's published values for comparison.
//!
//! Run: cargo bench --bench table2_memory

use khf::basis::{BasisName, BasisSet};
use khf::chem::graphene::PaperSystem;
use khf::coordinator::{report, BenchJson};
use khf::hf::memmodel::{self, EngineKind};
use khf::integrals::{ShellPairStore, SortedPairList};

fn gb(b: f64) -> String {
    format!("{:.2}", b / 1e9)
}

fn main() {
    let mut json = BenchJson::new("table2_memory");
    // Paper Table 2 (GB): (system, MPI, PrF, ShF).
    let paper: [(&str, f64, f64, f64); 5] = [
        ("0.5 nm", 7.0, 0.13, 0.03),
        ("1.0 nm", 48.0, 1.0, 0.2),
        ("1.5 nm", 160.0, 3.0, 0.8),
        ("2.0 nm", 417.0, 8.0, 2.0),
        ("5.0 nm", 9869.0, 257.0, 52.0),
    ];

    println!("== Table 2: memory footprint per node (GB, decimal) ==");
    println!("   MPI: 256 ranks/node; hybrids: 4 ranks/node x 64 threads\n");
    let mut rows = vec![vec![
        "system".into(),
        "BFs".into(),
        "MPI paper".into(),
        "MPI exact".into(),
        "MPI eq3a".into(),
        "PrF paper".into(),
        "PrF exact".into(),
        "PrF eq3b".into(),
        "ShF paper".into(),
        "ShF exact".into(),
        "ShF eq3c".into(),
    ]];
    for (k, sys) in PaperSystem::ALL.iter().enumerate() {
        let n = sys.n_bf();
        let mpi = memmodel::exact_bytes(EngineKind::MpiOnly, n, 15, 256, 1);
        let prf = memmodel::exact_bytes(EngineKind::PrivateFock, n, 15, 4, 64);
        let shf = memmodel::exact_bytes(EngineKind::SharedFock, n, 15, 4, 64);
        json.row(sys.label(), "mpi_exact_bytes", mpi);
        json.row(sys.label(), "private_exact_bytes", prf);
        json.row(sys.label(), "shared_exact_bytes", shf);
        rows.push(vec![
            sys.label().into(),
            n.to_string(),
            format!("{}", paper[k].1),
            gb(mpi),
            gb(memmodel::eq3a_mpi(n, 256)),
            format!("{}", paper[k].2),
            gb(prf),
            gb(memmodel::eq3b_private(n, 64, 4)),
            format!("{}", paper[k].3),
            gb(shf),
            gb(memmodel::eq3c_shared(n, 4)),
        ]);
    }
    print!("{}", report::table(&rows));

    println!("\n== Shell-pair store: replicated vs sharded vs ring (MPI-only, 256 ranks/node) ==");
    println!("   sharded gate figures: max shard at 1.5x the even split, shared ket");
    println!("   prefix window at 0.3x one copy (held once per node); ring: own +");
    println!("   visiting block per rank, no window; overlapped ring (--ring-overlap)");
    println!("   adds a prefetch block (3 resident); ring bytes/build = the (N-1)");
    println!("   block copies each rank receives per rebuild (bytes moved, not time)\n");
    let mut rows = vec![vec![
        "system".into(),
        "store/copy".into(),
        "replicated/node".into(),
        "sharded/node".into(),
        "ring/node".into(),
        "ovl ring/node".into(),
        "total repl.".into(),
        "total sharded".into(),
        "total ring".into(),
        "ring bytes/build".into(),
        "feasible (repl/shard/ring)".into(),
    ]];
    for sys in PaperSystem::ALL {
        let n = sys.n_bf();
        let basis = BasisSet::assemble(&sys.build(), BasisName::SixThirtyOneGd)
            .expect("paper system basis");
        let sb = ShellPairStore::estimate_bytes(&basis) as f64;
        let pl = SortedPairList::estimate_bytes_for(ShellPairStore::estimate_pair_count(
            &basis,
        )) as f64;
        let repl_store = memmodel::shared_scf_bytes_per_node(sb, pl, 256);
        let shard_store =
            memmodel::sharded_scf_bytes_per_node(sb / 256.0 * 1.5, 0.3 * sb, pl, 256);
        let ring_store = memmodel::ring_scf_bytes_per_node(sb / 256.0 * 1.5, pl, 256);
        let ovl_store =
            memmodel::ring_overlap_scf_bytes_per_node(sb / 256.0 * 1.5, pl, 256);
        let total_repl =
            memmodel::exact_bytes_with_store(EngineKind::MpiOnly, n, 15, 256, 1, sb, pl);
        let total_shard = memmodel::exact_bytes_with_sharded_store(
            EngineKind::MpiOnly,
            n,
            15,
            256,
            1,
            sb / 256.0 * 1.5,
            0.3 * sb,
            pl,
        );
        let total_ring = memmodel::exact_bytes_with_ring_store(
            EngineKind::MpiOnly,
            n,
            15,
            256,
            1,
            sb / 256.0 * 1.5,
            pl,
        );
        // One-node sweep: each of the 256 ranks receives the other 255
        // blocks once per build. This column is bytes moved, not time —
        // the simulator's `Breakdown::ring_pass_seconds` charges the
        // time equivalent.
        let ring_bytes = 255.0 * sb;
        json.row(sys.label(), "replicated_store_bytes_per_node", repl_store);
        json.row(sys.label(), "sharded_store_bytes_per_node", shard_store);
        json.row(sys.label(), "ring_store_bytes_per_node", ring_store);
        json.row(sys.label(), "ring_overlap_store_bytes_per_node", ovl_store);
        json.row(sys.label(), "ring_bytes_per_build", ring_bytes);
        rows.push(vec![
            sys.label().into(),
            gb(sb),
            gb(repl_store),
            gb(shard_store),
            gb(ring_store),
            gb(ovl_store),
            gb(total_repl),
            gb(total_shard),
            gb(total_ring),
            gb(ring_bytes),
            format!(
                "{}/{}/{}",
                memmodel::feasible(total_repl, false),
                memmodel::feasible(total_shard, false),
                memmodel::feasible(total_ring, false)
            ),
        ]);
    }
    print!("{}", report::table(&rows));

    println!("\n== Class-batch drain buffers (per node, hybrids: 4 ranks x 64 threads) ==");
    println!("   Every engine thread owns one fill-and-flush QuartetBatch (classes^2");
    println!("   buckets x batch sites); hetero owns two (offload + host split) plus");
    println!("   a batch x maxShellBF^4 staged ERI slab per thread. All figures are");
    println!("   N_BF-independent — the Table 2 matrix story is untouched.\n");
    let (classes, batch, threads_node) = (3usize, 32usize, 4 * 64);
    let one_set = memmodel::batch_buffer_bytes_per_node(classes, batch, 1, 4, 64);
    let hetero_sets = memmodel::batch_buffer_bytes_per_node(classes, batch, 2, 4, 64);
    let hetero_stage =
        memmodel::hetero_stage_bytes_per_thread(batch, 15) * threads_node as f64;
    json.row("hybrid-node", "batch_buffer_bytes_per_node", one_set);
    json.row("hybrid-node", "hetero_batch_buffer_bytes_per_node", hetero_sets);
    json.row("hybrid-node", "hetero_stage_bytes_per_node", hetero_stage);
    let mut rows = vec![vec!["engine".into(), "buffers/node".into(), "stage/node".into()]];
    rows.push(vec![
        "mpi/private/shared (1 set)".into(),
        format!("{:.2} MB", one_set / 1e6),
        "-".into(),
    ]);
    rows.push(vec![
        "hetero (2 sets + slab)".into(),
        format!("{:.2} MB", hetero_sets / 1e6),
        format!("{:.2} MB", hetero_stage / 1e6),
    ]);
    print!("{}", report::table(&rows));

    println!("\n== Headline reduction factors (exact accounting) ==");
    let mut rows = vec![vec![
        "system".into(),
        "MPI/PrF".into(),
        "MPI/ShF".into(),
        "paper claims".into(),
    ]];
    for sys in PaperSystem::ALL {
        let n = sys.n_bf();
        let mpi = memmodel::exact_bytes(EngineKind::MpiOnly, n, 15, 256, 1);
        let prf = memmodel::exact_bytes(EngineKind::PrivateFock, n, 15, 4, 64);
        let shf = memmodel::exact_bytes(EngineKind::SharedFock, n, 15, 4, 64);
        rows.push(vec![
            sys.label().into(),
            format!("{:.0}x", mpi / prf),
            format!("{:.0}x", mpi / shf),
            "~50x / ~200x".into(),
        ]);
    }
    print!("{}", report::table(&rows));
    json.write();
}

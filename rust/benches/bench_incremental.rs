//! Benchmark: full-rebuild vs incremental (ΔD) direct SCF — quartets
//! computed per iteration and total Fock/wall time. The incremental
//! driver's density-weighted screen (Q_ij·Q_kl·w(ΔD) ≤ τ) should
//! collapse the late-iteration quartet counts while landing on the same
//! energy.
//!
//! Run: cargo bench --bench bench_incremental

use std::time::Instant;

use khf::basis::BasisName;
use khf::chem::{molecules, Molecule};
use khf::coordinator::report;
use khf::hf::serial::SerialFock;
use khf::scf::RhfDriver;
use khf::util::human_secs;

fn run_case(mol: &Molecule, basis: BasisName, incremental: bool) {
    let driver = RhfDriver {
        incremental,
        // Never force a late full rebuild here: the point is to show the
        // pure ΔD trajectory. Production keeps the default cadence.
        rebuild_every: 0,
        ..Default::default()
    };
    let mut builder = SerialFock::new();
    let t0 = Instant::now();
    let res = driver.run(mol, basis, &mut builder).expect("scf");
    let wall = t0.elapsed().as_secs_f64();

    let mode = if incremental { "incremental" } else { "full-rebuild" };
    println!(
        "-- {} / {} [{mode}]: E = {:.8} Ha, {} iterations, converged={}",
        mol.name,
        basis.label(),
        res.energy,
        res.iterations,
        res.converged
    );
    let mut rows = vec![vec![
        "iter".into(),
        "computed".into(),
        "screened".into(),
        "build time".into(),
    ]];
    for (it, st) in res.build_stats.iter().enumerate() {
        rows.push(vec![
            (it + 1).to_string(),
            st.quartets_computed.to_string(),
            st.quartets_screened.to_string(),
            human_secs(st.seconds),
        ]);
    }
    print!("{}", report::table(&rows));
    let total: u64 = res.build_stats.iter().map(|s| s.quartets_computed).sum();
    let first = res.build_stats.first().map(|s| s.quartets_computed).unwrap_or(0);
    let last = res.build_stats.last().map(|s| s.quartets_computed).unwrap_or(0);
    println!(
        "   totals: {total} quartets over {} builds (first {first} -> final {last}), \
         Fock {} / wall {}\n",
        res.build_stats.len(),
        human_secs(res.fock_build_seconds),
        human_secs(wall),
    );
}

fn main() {
    println!("== Incremental (ΔD) vs full-rebuild direct SCF ==\n");
    for (mol, basis) in [
        (molecules::methane(), BasisName::SixThirtyOneG),
        (molecules::benzene(), BasisName::Sto3g),
    ] {
        run_case(&mol, basis, false);
        run_case(&mol, basis, true);
    }
    println!(
        "note: both modes share the SCF-lifetime ShellPairStore; the win measured here\n\
         is purely the density-weighted ΔD screening of the quartet space."
    );
}

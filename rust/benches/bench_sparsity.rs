//! Benchmark: LinK significance lists vs the two-key walk on a
//! graphene sheet scaling series.
//!
//! The two-key walk visits exactly the factorized survivor set
//! `Q_ij·Q_kl·max(w_ij, w_kl) > τ`; the significance lists re-filter
//! that stream with the *unfactorized* bound `Q_ij·Q_kl·w(ij,kl)`,
//! whose cross-block exchange weights decay with bra–ket distance. On
//! a growing sheet the factorized row maxima stay fat (every bra has
//! *some* nearby dense partner) while the per-quartet weights thin
//! out, so the list-backed visited count must grow strictly slower
//! than the two-key count — the O(N)-sparse exchange claim, asserted
//! here from measured values across a ≥3-point series, never from
//! hardcoded numbers.
//!
//! Each sheet gets a short serial SCF first: the lists only bite on a
//! physical, spatially decaying density (a random density has no
//! structure to exploit), and convergence is irrelevant — only the
//! density's shape matters.
//!
//! Run: cargo bench --bench bench_sparsity
//! (Numbers land in EXPERIMENTS.md §9; rows in BENCH_sparsity.json.)

use khf::basis::{BasisName, BasisSet};
use khf::chem::graphene;
use khf::coordinator::{report, BenchJson};
use khf::hf::serial::SerialFock;
use khf::integrals::{
    PairDensityMax, PairWalk, SchwarzScreen, ShellPairStore, SortedPairList,
};
use khf::scf::RhfDriver;
use khf::util::timer;

struct Row {
    label: String,
    n_shells: usize,
    pairs_listed: usize,
    two_key: u64,
    listed: u64,
    elided: u64,
    list_bytes: usize,
    /// Mean seconds to enumerate the full walk (kets of every task).
    t_two: f64,
    t_list: f64,
}

/// Enumerate every (task, ket) of a walk — what an engine's claim loop
/// pays before any ERI work.
fn enumerate_walk(walk: &PairWalk) -> u64 {
    let mut kept = 0u64;
    for t in 0..walk.n_tasks() {
        let rij = walk.task(t);
        kept += walk.kets(rij).iter().count() as u64;
    }
    kept
}

fn run_sheet(n_atoms: usize) -> Row {
    let label = format!("sheet:{n_atoms}");
    let mol = graphene::monolayer(n_atoms, &label);
    let basis = BasisSet::assemble(&mol, BasisName::Sto3g).expect("basis");
    // Short SCF for a physically structured density (see module doc).
    let driver = RhfDriver { max_iter: 5, ..Default::default() };
    let res = driver
        .run_with_basis(&mol, &basis, &mut SerialFock::new())
        .expect("scf");

    let store = ShellPairStore::build(&basis);
    let screen = SchwarzScreen::build_with_store(&basis, &store, SchwarzScreen::DEFAULT_TAU);
    let pairs = SortedPairList::build(&screen, &store);
    let dmax = PairDensityMax::build(&basis, &res.density);

    let two = pairs.weighted(&dmax);
    let link = pairs.weighted_linked(&dmax);
    let sig = link.sig().expect("list-backed walk").stats();
    let two_key = two.n_visited();

    let st_two = timer::bench(2, 8, 0.2, || {
        timer::black_box(&enumerate_walk(&two));
    });
    let st_list = timer::bench(2, 8, 0.2, || {
        timer::black_box(&enumerate_walk(&link));
    });

    Row {
        label,
        n_shells: basis.n_shells(),
        pairs_listed: pairs.len(),
        two_key,
        listed: sig.listed,
        elided: sig.elided,
        list_bytes: sig.bytes,
        t_two: st_two.mean,
        t_list: st_list.mean,
    }
}

fn main() {
    println!("== Significance lists vs two-key walk: graphene sheet series ==\n");
    let sizes = [12usize, 24, 40];
    let rows: Vec<Row> = sizes.iter().map(|&n| run_sheet(n)).collect();

    let mut table = vec![vec![
        "system".into(),
        "shells".into(),
        "pairs".into(),
        "two-key visited".into(),
        "list visited".into(),
        "elided".into(),
        "list/two-key".into(),
        "list bytes".into(),
        "walk two-key".into(),
        "walk list".into(),
    ]];
    let mut bj = BenchJson::new("sparsity");
    for r in &rows {
        let frac = r.listed as f64 / r.two_key.max(1) as f64;
        table.push(vec![
            r.label.clone(),
            r.n_shells.to_string(),
            r.pairs_listed.to_string(),
            r.two_key.to_string(),
            r.listed.to_string(),
            r.elided.to_string(),
            format!("{:.3}", frac),
            khf::util::human_bytes(r.list_bytes as f64),
            khf::util::human_secs(r.t_two),
            khf::util::human_secs(r.t_list),
        ]);
        bj.row(&r.label, "two_key_visited", r.two_key as f64);
        bj.row(&r.label, "list_visited", r.listed as f64);
        bj.row(&r.label, "quartets_elided", r.elided as f64);
        bj.row(&r.label, "list_fraction", frac);
        bj.row(&r.label, "list_bytes", r.list_bytes as f64);
        bj.row(&r.label, "walk_seconds_two_key", r.t_two);
        bj.row(&r.label, "walk_seconds_list", r.t_list);
    }
    print!("{}", report::table(&table));

    // Structural invariants, per size: the lists partition the two-key
    // stream and actually elide work.
    for r in &rows {
        assert!(r.listed <= r.two_key, "{}: lists must nest", r.label);
        assert_eq!(r.listed + r.elided, r.two_key, "{}: partition broken", r.label);
        assert!(r.elided > 0, "{}: no elision at physical density", r.label);
    }
    // The scaling claim, from measured values: between every pair of
    // consecutive sheet sizes the list-backed visited count grows
    // strictly slower than the two-key count (equivalently, the
    // list/two-key fraction falls as the sheet grows).
    for w in rows.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        let g_two = b.two_key as f64 / a.two_key.max(1) as f64;
        let g_list = b.listed as f64 / a.listed.max(1) as f64;
        assert!(
            g_list < g_two,
            "{} -> {}: list growth {g_list:.3}x must trail two-key growth {g_two:.3}x",
            a.label,
            b.label
        );
    }
    println!(
        "\nnote: 'list visited' is the exact unfactorized-bound survivor set\n\
         Q_ij·Q_kl·w(ij,kl) > tau — a subset of the two-key walk's factorized set\n\
         (max(w_ij, w_kl) carries row maxima that any nearby dense partner keeps\n\
         fat). The fraction falling with sheet size is the O(N)-sparse exchange\n\
         trend; the assertions above pin it from the measured series."
    );

    bj.write();
}

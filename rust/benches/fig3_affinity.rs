//! Paper Figure 3 — shared-Fock performance vs OpenMP thread count for
//! the four KMP_AFFINITY policies (1.0 nm, 4 ranks, 1–64 threads/rank,
//! quad-cache; simulated KNL node).
//!
//! Run: cargo bench --bench fig3_affinity

use khf::chem::graphene::PaperSystem;
use khf::cluster::knl::Affinity;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system};
use khf::hf::memmodel::EngineKind;

fn main() {
    khf::util::logging::init();
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(PaperSystem::Nm10, &cost).expect("stats");

    println!("== Fig 3: shared-Fock time vs threads/rank by affinity (1.0 nm, 4 ranks) ==\n");
    let mut rows = vec![vec![
        "threads/rank".into(),
        "compact".into(),
        "scatter".into(),
        "balanced".into(),
        "none".into(),
    ]];
    for t in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut row = vec![t.to_string()];
        for aff in Affinity::ALL {
            let m = Machine {
                nodes: 1,
                ranks_per_node: 4,
                threads_per_rank: t,
                affinity: aff,
                mcdram_only: true,
                ..Machine::theta_hybrid(1)
            };
            let r = simulate(EngineKind::SharedFock, &stats, &m, &cost);
            row.push(report::secs(r.fock_seconds));
        }
        rows.push(row);
    }
    print!("{}", report::table(&rows));
    println!(
        "\npaper shape: scaling is near-linear to 16 threads/rank (64 hw threads = 1/core),\n\
         gains continue to 2 threads/core then flatten; affinity choice is a small effect\n\
         with balanced/scatter best and none worst."
    );
}

//! Microbenchmark: ERI shell-quartet throughput per angular-momentum
//! class — the calibration source for the simulator and the §Perf L3
//! hot-path baseline.
//!
//! Run: cargo bench --bench bench_eri

use khf::basis::{BasisName, BasisSet};
use khf::chem::graphene;
use khf::cluster::costmodel::pair_class;
use khf::coordinator::report;
use khf::hf::scatter::scatter_block;
use khf::integrals::{EriEngine, ShellPairStore};
use khf::linalg::Matrix;
use khf::util::timer;

fn main() {
    let mol = graphene::bilayer(8, "c16");
    let basis = BasisSet::assemble(&mol, BasisName::SixThirtyOneGd).unwrap();
    let cls: Vec<usize> = basis.shells.iter().map(|s| s.class).collect();
    let class_names = ["S6", "L3", "L1", "D1"];

    // One representative quartet per (bra, ket) pair-class.
    let nsh = basis.n_shells();
    let mut rep = vec![None; 100];
    for i in 0..nsh {
        for j in 0..=i {
            for k in 0..=i {
                let lmax = if k == i { j } else { k };
                for l in 0..=lmax {
                    let key = pair_class(cls[i], cls[j]) * 10 + pair_class(cls[k], cls[l]);
                    rep[key].get_or_insert((i, j, k, l));
                }
            }
        }
    }

    let store = ShellPairStore::build(&basis);
    println!(
        "shell-pair store: {} pairs, {} prim pairs, {}\n",
        store.n_pairs_stored(),
        store.n_prim_pairs(),
        khf::util::human_bytes(store.bytes() as f64)
    );
    let mut eng = EriEngine::new();
    let mut block = vec![0.0; 6 * 6 * 6 * 6];
    let d = Matrix::identity(basis.n_bf);
    let mut g = Matrix::zeros(basis.n_bf, basis.n_bf);

    println!("== ERI quartet cost by pair-class combination (host core) ==\n");
    let mut rows = vec![vec!["bra".into(), "ket".into(), "ns/quartet".into(), "quartets/s".into()]];
    let pair_label = |pc: usize| -> String {
        // invert canonical pair index over 4 classes
        for a in 0..4 {
            for b in 0..=a {
                if pair_class(a, b) == pc {
                    return format!("({},{})", class_names[a], class_names[b]);
                }
            }
        }
        format!("pc{pc}")
    };
    for bpc in 0..10 {
        for kpc in 0..10 {
            let Some((i, j, k, l)) = rep[bpc * 10 + kpc] else { continue };
            if kpc > bpc {
                continue; // symmetric; keep the table compact
            }
            let st = timer::bench(50, 5000, 0.05, || {
                eng.shell_quartet(&basis, &store, i, j, k, l, &mut block);
                scatter_block(&basis, (i, j, k, l), &block, &d, &mut |a, b, v| {
                    g.add(a, b, v)
                });
            });
            rows.push(vec![
                pair_label(bpc),
                pair_label(kpc),
                format!("{:.0}", st.mean * 1e9),
                format!("{:.2e}", 1.0 / st.mean),
            ]);
        }
    }
    print!("{}", report::table(&rows));
    timer::black_box(&g);

    // Whole-build throughput on a small real system.
    let screen = khf::integrals::SchwarzScreen::build_with_store(&basis, &store, 1e-10);
    let pairs = khf::integrals::SortedPairList::build(&screen, &store);
    let mut serial = khf::hf::serial::SerialFock::new();
    let dm = Matrix::identity(basis.n_bf);
    use khf::hf::{FockBuilder, FockContext};
    let ctx = FockContext::new(&basis, &store, &screen, &pairs, &dm);
    let st = timer::bench(1, 3, 0.1, || {
        timer::black_box(serial.build_2e(&ctx));
    });
    println!(
        "\nfull c16 Fock build: {} ({} quartets -> {:.2e} quartets/s)",
        st,
        serial.stats.quartets_computed,
        serial.stats.quartets_computed as f64 / st.mean
    );
}

//! Paper Figure 5 — time-to-solution across KNL cluster/memory modes
//! for the small (0.5 nm) and large (2.0 nm) systems, three codes
//! (simulated; the mode factors encode the paper's measured ordering —
//! see cluster::knl::mode_penalty).
//!
//! Run: cargo bench --bench fig5_modes

use khf::basis::BasisName;
use khf::chem::graphene::PaperSystem;
use khf::chem::molecules;
use khf::cluster::knl::{ClusterMode, MemoryMode};
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system, BenchJson};
use khf::hf::hetero_fock::HeteroFock;
use khf::hf::memmodel::EngineKind;
use khf::hf::serial::SerialFock;
use khf::scf::RhfDriver;

fn main() {
    khf::util::logging::init();
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let mut json = BenchJson::new("fig5_modes");

    for sys in [PaperSystem::Nm05, PaperSystem::Nm20] {
        let stats = stats_for_system(sys, &cost).expect("stats");
        println!("== Fig 5: {} — single node, all cluster x memory modes ==\n", sys.label());
        let mut rows = vec![vec![
            "mode".into(),
            "MPI-only".into(),
            "Private Fock".into(),
            "Shared Fock".into(),
        ]];
        for cl in ClusterMode::ALL {
            for mem in MemoryMode::ALL {
                let hybrid = Machine {
                    cluster_mode: cl,
                    memory_mode: mem,
                    ..Machine::theta_hybrid(1)
                };
                let mpi_m = Machine {
                    cluster_mode: cl,
                    memory_mode: mem,
                    ..Machine::theta_mpi(1)
                };
                let mpi = simulate(EngineKind::MpiOnly, &stats, &mpi_m, &cost);
                let prf = simulate(EngineKind::PrivateFock, &stats, &hybrid, &cost);
                let shf = simulate(EngineKind::SharedFock, &stats, &hybrid, &cost);
                let config = format!("{}/{}-{}", sys.label(), cl.label(), mem.label());
                json.row(&config, "mpi_fock_seconds", mpi.fock_seconds);
                json.row(&config, "private_fock_seconds", prf.fock_seconds);
                json.row(&config, "shared_fock_seconds", shf.fock_seconds);
                rows.push(vec![
                    format!("{}-{}", cl.label(), mem.label()),
                    report::secs(mpi.fock_seconds),
                    report::secs(prf.fock_seconds),
                    report::secs(shf.fock_seconds),
                ]);
            }
        }
        print!("{}", report::table(&rows));
        println!(
            "\npaper shape: private Fock best in every mode; shared Fock beats MPI-only in\n\
             all modes except all-to-all (small system), where they flip; quad-cache best.\n"
        );
    }

    // Real-engine addendum: the heterogeneous class-split engine vs the
    // serial baseline on a molecule this host can actually run (the
    // mode table above is simulated — hetero has no KNL-mode analogue,
    // so it reports measured Fock seconds and its drain split instead).
    println!("== hetero engine (measured, benzene/STO-3G, 1 rank x 4 threads) ==\n");
    let mol = molecules::benzene();
    let serial = RhfDriver::default()
        .run(&mol, BasisName::Sto3g, &mut SerialFock::new())
        .expect("serial scf");
    let mut h = HeteroFock::new(1, 4);
    let hetero = RhfDriver::default().run(&mol, BasisName::Sto3g, &mut h).expect("hetero scf");
    let first = hetero.build_stats.first().expect("stats");
    println!(
        "serial {:.2} s vs hetero {:.2} s Fock time; dE = {:.2e}; first build \
         {} batches + {} tail quartets ({} accelerated)",
        serial.fock_build_seconds,
        hetero.fock_build_seconds,
        (serial.energy - hetero.energy).abs(),
        first.batches_flushed,
        first.tail_quartets,
        first.accel_batches,
    );
    json.row("benzene/measured", "serial_fock_seconds", serial.fock_build_seconds);
    json.row("benzene/measured", "hetero_fock_seconds", hetero.fock_build_seconds);
    json.row("benzene/measured", "hetero_accel_batches", first.accel_batches as f64);
    json.write();
}

"""AOT bridge: lower the Layer-2 functions (with their Layer-1 Pallas
kernels inlined via interpret mode) to HLO **text** artifacts for the
Rust PJRT runtime.

HLO text — not serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts] [--sizes 8,16,...]
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # SCF needs f64

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Must match rust/src/runtime/mod.rs::SIZE_GRID.
SIZE_GRID = [8, 16, 32, 40, 64]
# Column-buffer flush artifact shape (mxsize x nthreads).
COLREDUCE_SHAPE = (4096, 64)
# Blocked J/K batch shape (batch x padded shell width). Must match the
# Rust defaults: hf::DEFAULT_BATCH_SIZE and the cartesian d-shell width.
BLOCKJK_SHAPE = (32, 6)
DTYPE = jnp.float64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifacts(sizes):
    """Yield (name, hlo_text) for every artifact."""
    for n in sizes:
        eri = jax.ShapeDtypeStruct((n, n, n, n), DTYPE)
        mat = jax.ShapeDtypeStruct((n, n), DTYPE)
        vec = jax.ShapeDtypeStruct((n,), DTYPE)
        yield f"fock2e_{n}", to_hlo_text(jax.jit(model.fock2e).lower(eri, mat))
        yield f"density_{n}", to_hlo_text(jax.jit(model.density).lower(mat, vec))
        yield f"fock_energy_{n}", to_hlo_text(
            jax.jit(model.fock_energy).lower(eri, mat, mat)
        )
    m, t = COLREDUCE_SHAPE
    buf = jax.ShapeDtypeStruct((m, t), DTYPE)
    yield f"colreduce_{m}_{t}", to_hlo_text(jax.jit(model.colreduce_flush).lower(buf))
    b, w = BLOCKJK_SHAPE
    blocks = jax.ShapeDtypeStruct((b, w, w, w, w), DTYPE)
    dstack = jax.ShapeDtypeStruct((6, b, w, w), DTYPE)
    yield f"blockjk_{b}_{w}", to_hlo_text(jax.jit(model.blockjk_planes).lower(blocks, dstack))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in SIZE_GRID))
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)
    total = 0
    for name, text in lower_artifacts(sizes):
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        total += 1
        print(f"wrote {path} ({len(text)} chars)")
    print(f"{total} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()

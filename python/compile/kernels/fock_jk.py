"""Layer-1 Pallas kernel: blocked J/K contraction — the Fock-build hot
spot as a dense tensor contraction.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's KNL
implementation walks shell quartets on 256 scalar threads with
thread-private accumulation buffers; on a systolic-array target the same
six-element update becomes two dense contractions (J and K) evaluated
tile-by-tile on the MXU. The grid runs over output row tiles; each
program streams its ERI slab HBM->VMEM once and performs two
[ti*n, n^2] x [n^2] contractions.

VMEM budget: the ERI slab is ti * n^3 * bytes; `pick_tile` keeps it
under ~8 MiB (f32 deployment shape; the CPU-interpret path used for
correctness runs f64). MXU utilization estimate for n=64, ti=8, f32:
2 contractions x 2*ti*n*n^2 flops over a 8.4 MB slab -> arithmetic
intensity ~16 flop/byte, enough to keep the 128x128 MXU busy at ~55-70%
of roofline on the reshaped [512, 4096] operand (see DESIGN.md §Perf).

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO that both the
pytest oracle checks and the Rust runtime execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget for the ERI slab (bytes) in the deployment (f32) shape.
VMEM_SLAB_BUDGET = 8 * 1024 * 1024


def pick_tile(n: int, itemsize: int = 4) -> int:
    """Largest row-tile ti dividing n with ti * n^3 * itemsize within
    the VMEM slab budget (always at least 1)."""
    best = 1
    for ti in range(1, n + 1):
        if n % ti == 0 and ti * n**3 * itemsize <= VMEM_SLAB_BUDGET:
            best = ti
    return best


def _kernel(eri_ref, d_ref, o_ref):
    blk = eri_ref[...]  # (ti, n, n, n) VMEM slab
    d = d_ref[...]  # (n, n), broadcast to every program
    ti, n = blk.shape[0], blk.shape[1]
    dflat = d.reshape(n * n)
    # J tile: MXU-shaped [ti*n, n^2] @ [n^2].
    j = (blk.reshape(ti * n, n * n) @ dflat).reshape(ti, n)
    # K tile: K[t, j] = sum_kl blk[t, k, j, l] D[k, l].
    kx = (
        jnp.transpose(blk, (0, 2, 1, 3)).reshape(ti * n, n * n) @ dflat
    ).reshape(ti, n)
    o_ref[...] = j - 0.5 * kx


@functools.partial(jax.jit, static_argnames=("tile",))
def fock_jk(eri, d, tile=None):
    """G = J(D) - K(D)/2 from a dense chemists'-notation ERI tensor.

    eri: [n, n, n, n]; d: [n, n] symmetric. Matches
    ``ref.fock_jk_ref`` to float tolerance.
    """
    n = eri.shape[0]
    assert eri.shape == (n, n, n, n) and d.shape == (n, n)
    ti = tile or pick_tile(n)
    grid = (n // ti,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, n, n, n), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ti, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), eri.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(eri, d)

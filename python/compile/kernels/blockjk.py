"""Layer-1 Pallas kernel: batched same-class blocked J/K contraction —
the heterogeneous engine's offload unit.

Where `fock_jk` contracts the *dense* ERI tensor (small molecules that
fit the size grid), `blockjk` serves the sparse-direct path: the host
walks the screened quartet list, batches surviving quartets by angular
momentum class, and ships each full bucket — B same-shape ERI blocks
zero-padded to width w, plus six gathered density slices per block — to
this kernel. Each block yields the six per-quartet Fock updates of
eqs. (2a)-(2f) as dense plane contractions:

    out0[a,b] =  2   sum_{c,e} g[a,b,c,e] D(lam_c, sig_e)   J(mu nu)
    out1[c,e] =  2   sum_{a,b} g[a,b,c,e] D(mu_a,  nu_b)    J(lam sig)
    out2[a,c] = -1/2 sum_{b,e} g[a,b,c,e] D(nu_b,  sig_e)   K(mu lam)
    out3[a,e] = -1/2 sum_{b,c} g[a,b,c,e] D(nu_b,  lam_c)   K(mu sig)
    out4[b,c] = -1/2 sum_{a,e} g[a,b,c,e] D(mu_a,  sig_e)   K(nu lam)
    out5[b,e] = -1/2 sum_{a,c} g[a,b,c,e] D(mu_a,  lam_c)   K(nu sig)

The grid runs over the batch axis; each program holds one w^4 slab and
its six w^2 slices in VMEM (w <= 6: a few tens of KiB, far under
budget) and performs six [w^2, w^2] x [w^2] contractions. Zero padding
is exact: padded ERI entries and density slices are zero, and the host
scatters only the real dims region of each output plane.

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO that both the
pytest oracle checks and the Rust runtime execute.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(eri_ref, d_ref, o_ref):
    blk = eri_ref[...]  # (1, w, w, w, w) — one quartet's padded slab
    dsl = d_ref[...]  # (6, 1, w, w) — its six gathered density slices
    w = blk.shape[1]
    g = blk.reshape(w, w, w, w)
    d = dsl.reshape(6, w, w)
    gf = g.reshape(w * w, w * w)  # rows (a,b), cols (c,e)
    planes = [
        2.0 * (gf @ d[0].reshape(w * w)).reshape(w, w),
        2.0 * (d[1].reshape(w * w) @ gf).reshape(w, w),
        -0.5
        * (jnp.transpose(g, (0, 2, 1, 3)).reshape(w * w, w * w) @ d[2].reshape(w * w)).reshape(
            w, w
        ),
        -0.5
        * (jnp.transpose(g, (0, 3, 1, 2)).reshape(w * w, w * w) @ d[3].reshape(w * w)).reshape(
            w, w
        ),
        -0.5
        * (jnp.transpose(g, (1, 2, 0, 3)).reshape(w * w, w * w) @ d[4].reshape(w * w)).reshape(
            w, w
        ),
        -0.5
        * (jnp.transpose(g, (1, 3, 0, 2)).reshape(w * w, w * w) @ d[5].reshape(w * w)).reshape(
            w, w
        ),
    ]
    o_ref[...] = jnp.stack(planes).reshape(6, 1, w, w)


@jax.jit
def blockjk(eri, dstack):
    """Six weighted J/K output planes per quartet of a same-class batch.

    eri: [B, w, w, w, w] zero-padded ERI blocks; dstack: [6, B, w, w]
    gathered density slices in the order D(lam sig), D(mu nu),
    D(nu sig), D(nu lam), D(mu sig), D(mu lam). Returns [6, B, w, w].
    """
    b, w = eri.shape[0], eri.shape[1]
    assert eri.shape == (b, w, w, w, w) and dstack.shape == (6, b, w, w)
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, w, w, w, w), lambda n: (n, 0, 0, 0, 0)),
            pl.BlockSpec((6, 1, w, w), lambda n: (0, n, 0, 0)),
        ],
        out_specs=pl.BlockSpec((6, 1, w, w), lambda n: (0, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((6, b, w, w), eri.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(eri, dstack)

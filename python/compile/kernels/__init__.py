"""Layer-1 Pallas kernels (build-time only; lowered into HLO by aot.py)."""

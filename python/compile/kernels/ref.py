"""Pure-jnp oracles for the Pallas kernels — the correctness reference
pytest checks every kernel against (and the spec of what each kernel
computes)."""

import jax.numpy as jnp


def fock_jk_ref(eri, d):
    """Closed-shell two-electron Fock matrix from a dense ERI tensor.

    G_ij = sum_kl D_kl [ (ij|kl) - 1/2 (ik|jl) ]  (RHF convention with
    D = 2 C_occ C_occ^T).

    eri: [n, n, n, n] in chemists' notation (ij|kl); d: [n, n].
    """
    j = jnp.einsum("ijkl,kl->ij", eri, d)
    k = jnp.einsum("ikjl,kl->ij", eri, d)
    return j - 0.5 * k


def density_ref(c, mask):
    """Closed-shell density D = 2 * C_occ C_occ^T with the occupied
    columns selected by a 0/1 mask (so one compiled artifact serves any
    electron count)."""
    cm = c * mask[None, :]
    return 2.0 * cm @ cm.T


def colreduce_ref(buffers):
    """Flush of the paper's per-thread column buffers (Figure 1 B):
    buffers [m, nthreads] -> column sum [m]."""
    return jnp.sum(buffers, axis=1)


def energy_ref(d, h, f):
    """Electronic energy 0.5 * sum(D * (H + F))."""
    return 0.5 * jnp.sum(d * (h + f))

"""Layer-1 Pallas kernel: the paper's Figure-1(B) column-buffer flush as
a chunked tree reduction.

On KNL the shared-Fock algorithm flushes per-thread column buffers
[mxsize x nthreads] into the Fock matrix with row-chunked, cache-line
padded tree reduction. The TPU rethink: the grid runs over row chunks
(the chunking that avoided false sharing becomes tile alignment), and
the reduction over the thread axis is a log2(nthreads)-step pairwise
tree performed in VMEM — the same dataflow, vectorized 8x128 instead of
cache-line-strided.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(buf_ref, o_ref):
    x = buf_ref[...]  # (chunk, t)
    t = x.shape[1]
    # Pairwise (tree) reduction — t is a power of two by construction.
    while t > 1:
        t //= 2
        x = x[:, :t] + x[:, t : 2 * t]
    o_ref[...] = x[:, 0]


@functools.partial(jax.jit, static_argnames=("chunk",))
def colreduce(buffers, chunk=None):
    """Sum thread columns: buffers [m, nthreads] -> [m].

    nthreads must be a power of two (pad with zero columns otherwise —
    the wrapper in model.py does). Matches ``ref.colreduce_ref``.
    """
    m, t = buffers.shape
    assert t & (t - 1) == 0, "thread axis must be a power of two"
    c = chunk or (256 if m % 256 == 0 else m)
    assert m % c == 0
    return pl.pallas_call(
        _kernel,
        grid=(m // c,),
        in_specs=[pl.BlockSpec((c, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), buffers.dtype),
        interpret=True,
    )(buffers)

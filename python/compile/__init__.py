"""Build-time compile path (Layers 1+2). Never imported at runtime —
the Rust coordinator consumes only the HLO-text artifacts that
``python -m compile.aot`` emits."""

"""Layer 2: the dense SCF compute graph in JAX, calling the Layer-1
Pallas kernels. AOT-lowered by aot.py; never imported at runtime.

Functions return tuples — the HLO bridge lowers with return_tuple=True
and the Rust side unpacks tuples uniformly.
"""

import jax.numpy as jnp

from .kernels.blockjk import blockjk
from .kernels.colreduce import colreduce
from .kernels.fock_jk import fock_jk


def fock2e(eri, d):
    """Two-electron Fock matrix G(D) — the paper's hot spot.

    The Rust coordinator calls the compiled artifact once per SCF
    iteration; the contraction itself is the Pallas fock_jk kernel.
    """
    return (fock_jk(eri, d),)


def density(c, mask):
    """Closed-shell density from MO coefficients and an occupation mask:
    D = 2 (C*mask)(C*mask)^T. The mask input keeps the artifact
    shape-generic over electron counts."""
    cm = c * mask[None, :]
    return (2.0 * cm @ cm.T,)


def fock_energy(eri, d, h):
    """Fused iteration step: F = H + G(D) and the electronic energy
    E = 0.5 sum(D*(H+F)) in one artifact (one fewer host round trip)."""
    g = fock_jk(eri, d)
    f = h + g
    e = 0.5 * jnp.sum(d * (h + f))
    return (f, e.reshape(()))


def blockjk_planes(eri, dstack):
    """Blocked J/K planes for one same-class quartet batch (the
    heterogeneous engine's offload unit). Returns the six planes as a
    tuple so the Rust side unpacks them positionally."""
    out = blockjk(eri, dstack)
    return (out[0], out[1], out[2], out[3], out[4], out[5])


def colreduce_flush(buffers):
    """The Figure-1(B) buffer flush as a standalone artifact (pads the
    thread axis to a power of two)."""
    m, t = buffers.shape
    tp = 1
    while tp < t:
        tp *= 2
    if tp != t:
        buffers = jnp.pad(buffers, ((0, 0), (0, tp - t)))
    return (colreduce(buffers),)

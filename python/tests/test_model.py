"""Layer-2 correctness: model functions vs oracles, plus a full dense
SCF loop in numpy driven through the model functions — the same
iteration the Rust coordinator runs through the compiled artifacts."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def toy_system(n, seed):
    """A random but physically-shaped toy: symmetric H, SPD S=I, ERI
    with 8-fold symmetry and positive-definite-ish diagonal."""
    rng = np.random.default_rng(seed)
    eri = rng.standard_normal((n, n, n, n)) * 0.05
    eri = eri + eri.transpose(1, 0, 2, 3)
    eri = eri + eri.transpose(0, 1, 3, 2)
    eri = eri + eri.transpose(2, 3, 0, 1)
    for i in range(n):
        for j in range(n):
            eri[i, j, i, j] += 1.0  # Schwarz-positive diagonal
    h = rng.standard_normal((n, n))
    h = (h + h.T) * 0.5 - np.eye(n) * 2.0
    return jnp.asarray(eri), jnp.asarray(h)


class TestModelFunctions:
    def test_fock2e_matches_ref(self):
        eri, _ = toy_system(6, 0)
        d = jnp.asarray(np.eye(6) * 0.5)
        (g,) = model.fock2e(eri, d)
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(ref.fock_jk_ref(eri, d)), atol=1e-11
        )

    @pytest.mark.parametrize("n_occ", [0, 1, 3, 6])
    def test_density_mask(self, n_occ):
        n = 6
        rng = np.random.default_rng(1)
        c = jnp.asarray(rng.standard_normal((n, n)))
        mask = jnp.asarray([1.0] * n_occ + [0.0] * (n - n_occ))
        (d,) = model.density(c, mask)
        want = 2.0 * np.asarray(c)[:, :n_occ] @ np.asarray(c)[:, :n_occ].T
        np.testing.assert_allclose(np.asarray(d), want, atol=1e-12)
        # Trace counts electrons when C is orthonormal.
        q, _ = np.linalg.qr(np.asarray(c))
        (d2,) = model.density(jnp.asarray(q), mask)
        assert abs(np.trace(np.asarray(d2)) - 2 * n_occ) < 1e-10

    def test_fock_energy_consistent(self):
        eri, h = toy_system(5, 2)
        rng = np.random.default_rng(3)
        d = rng.standard_normal((5, 5))
        d = jnp.asarray(d + d.T)
        f, e = model.fock_energy(eri, d, h)
        (g,) = model.fock2e(eri, d)
        np.testing.assert_allclose(np.asarray(f), np.asarray(h + g), atol=1e-11)
        want_e = ref.energy_ref(d, h, f)
        np.testing.assert_allclose(float(e), float(want_e), atol=1e-11)

    def test_colreduce_flush_pads_threads(self):
        rng = np.random.default_rng(4)
        buf = jnp.asarray(rng.standard_normal((64, 5)))  # non-power-of-two
        (out,) = model.colreduce_flush(buf)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(buf).sum(axis=1), atol=1e-12
        )


class TestDenseScf:
    def test_scf_converges_on_toy(self):
        """Full SCF loop over the model functions (the exact iteration
        the Rust runtime drives through the artifacts)."""
        n, n_occ = 8, 2
        eri, h = toy_system(n, 7)
        mask = jnp.asarray([1.0] * n_occ + [0.0] * (n - n_occ))
        d = jnp.zeros((n, n))
        e_prev, e = None, None
        for _ in range(60):
            f, e = model.fock_energy(eri, d, h)
            w, v = np.linalg.eigh(np.asarray(f))
            (d_new,) = model.density(jnp.asarray(v), mask)
            if e_prev is not None and abs(float(e) - e_prev) < 1e-10:
                break
            e_prev = float(e)
            d = 0.5 * (d + d_new)  # damped
        assert e_prev is not None
        assert abs(float(e) - e_prev) < 1e-8
        # Energy is real and below the empty-density reference (0).
        assert float(e) < 0.0

    def test_aot_lowering_produces_hlo(self):
        """The AOT path itself: every artifact lowers to parseable HLO
        text with the expected entry computation."""
        from compile import aot

        count = 0
        for name, text in aot.lower_artifacts([8]):
            assert "ENTRY" in text, name
            assert len(text) > 200, name
            count += 1
        assert count == 4  # fock2e, density, fock_energy, colreduce

"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles, swept
over shapes/dtypes/tiles with hypothesis."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.colreduce import colreduce
from compile.kernels.fock_jk import fock_jk, pick_tile


def random_eri(n, seed, dtype):
    rng = np.random.default_rng(seed)
    eri = rng.standard_normal((n, n, n, n))
    # Impose the physical 8-fold permutational symmetry.
    eri = eri + eri.transpose(1, 0, 2, 3)
    eri = eri + eri.transpose(0, 1, 3, 2)
    eri = eri + eri.transpose(2, 3, 0, 1)
    return jnp.asarray(eri, dtype=dtype)


def random_sym(n, seed, dtype):
    rng = np.random.default_rng(seed)
    d = rng.standard_normal((n, n))
    return jnp.asarray(d + d.T, dtype=dtype)


class TestFockJk:
    @pytest.mark.parametrize("n", [2, 4, 8, 12, 16])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
    def test_matches_ref(self, n, dtype):
        eri = random_eri(n, n, dtype)
        d = random_sym(n, n + 1, dtype)
        got = fock_jk(eri, d)
        want = ref.fock_jk_ref(eri, d)
        tol = 1e-4 if dtype == jnp.float32 else 1e-11
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)

    @pytest.mark.parametrize("tile", [1, 2, 4, 8])
    def test_tile_invariance(self, tile):
        n = 8
        eri = random_eri(n, 3, jnp.float64)
        d = random_sym(n, 4, jnp.float64)
        base = fock_jk(eri, d, tile=None)
        tiled = fock_jk(eri, d, tile=tile)
        np.testing.assert_allclose(np.asarray(base), np.asarray(tiled), atol=1e-12)

    def test_output_symmetric_for_symmetric_inputs(self):
        # Physical ERI symmetry + symmetric D => symmetric G.
        n = 8
        eri = random_eri(n, 7, jnp.float64)
        d = random_sym(n, 8, jnp.float64)
        g = np.asarray(fock_jk(eri, d))
        np.testing.assert_allclose(g, g.T, atol=1e-11)

    def test_zero_padding_is_exact(self):
        # Zero-padded rows/cols (the Rust runtime's grid rounding) must
        # not perturb the live block.
        n, npad = 6, 8
        eri = np.zeros((npad,) * 4)
        eri[:n, :n, :n, :n] = np.asarray(random_eri(n, 9, jnp.float64))
        d = np.zeros((npad, npad))
        d[:n, :n] = np.asarray(random_sym(n, 10, jnp.float64))
        g_pad = np.asarray(fock_jk(jnp.asarray(eri), jnp.asarray(d)))
        g = np.asarray(
            fock_jk(jnp.asarray(eri[:n, :n, :n, :n]), jnp.asarray(d[:n, :n]))
        )
        np.testing.assert_allclose(g_pad[:n, :n], g, atol=1e-12)
        np.testing.assert_allclose(g_pad[n:, :], 0.0, atol=1e-15)
        np.testing.assert_allclose(g_pad[:, n:], 0.0, atol=1e-15)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([2, 3, 4, 6, 8]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, n, seed):
        eri = random_eri(n, seed, jnp.float64)
        d = random_sym(n, seed ^ 0xABCDEF, jnp.float64)
        got = np.asarray(fock_jk(eri, d))
        want = np.asarray(ref.fock_jk_ref(eri, d))
        np.testing.assert_allclose(got, want, atol=1e-10)

    def test_pick_tile_respects_budget(self):
        for n in [8, 16, 32, 40, 64]:
            ti = pick_tile(n)
            assert n % ti == 0
            assert ti * n**3 * 4 <= 8 * 1024 * 1024 or ti == 1


class TestColreduce:
    @pytest.mark.parametrize("m,t", [(8, 2), (256, 4), (512, 64), (1024, 1)])
    def test_matches_ref(self, m, t):
        rng = np.random.default_rng(m * 1000 + t)
        buf = jnp.asarray(rng.standard_normal((m, t)))
        got = colreduce(buf)
        want = ref.colreduce_ref(buf)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)

    @settings(max_examples=20, deadline=None)
    @given(
        log_m=st.integers(min_value=1, max_value=10),
        log_t=st.integers(min_value=0, max_value=6),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, log_m, log_t, seed):
        m, t = 2**log_m, 2**log_t
        rng = np.random.default_rng(seed)
        buf = jnp.asarray(rng.standard_normal((m, t)))
        np.testing.assert_allclose(
            np.asarray(colreduce(buf)), np.asarray(ref.colreduce_ref(buf)), atol=1e-12
        )

    def test_chunking_invariance(self):
        m, t = 512, 8
        rng = np.random.default_rng(5)
        buf = jnp.asarray(rng.standard_normal((m, t)))
        a = colreduce(buf, chunk=m)
        b = colreduce(buf, chunk=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-13)

    def test_rejects_non_power_of_two(self):
        buf = jnp.zeros((8, 3))
        with pytest.raises(AssertionError):
            colreduce(buf)

//! Memory-footprint explorer — the Table 2 companion: sweep ranks ×
//! threads for any system and see which configurations fit MCDRAM /
//! DDR4 (the constraint that drives the paper's entire design).
//!
//! Run: cargo run --release --example memory_footprint -- [--system 1.0]

use khf::chem::graphene::PaperSystem;
use khf::coordinator::report;
use khf::hf::memmodel::{exact_bytes, EngineKind, DDR4_BYTES, MCDRAM_BYTES};
use khf::util::cli::Args;
use khf::util::human_bytes;

fn fit(bytes: f64) -> &'static str {
    if bytes <= MCDRAM_BYTES {
        "MCDRAM"
    } else if bytes <= DDR4_BYTES {
        "DDR4"
    } else {
        "DOES NOT FIT"
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let sys = PaperSystem::parse(args.get_or("system", "1.0"))
        .ok_or_else(|| anyhow::anyhow!("bad --system"))?;
    let n = sys.n_bf();

    println!("{}: {} basis functions\n", sys.label(), n);

    println!("-- MPI-only: ranks/node sweep (everything replicated) --");
    let mut rows = vec![vec!["ranks".into(), "bytes/node".into(), "fits in".into()]];
    for r in [4usize, 16, 64, 128, 256] {
        let b = exact_bytes(EngineKind::MpiOnly, n, 15, r, 1);
        rows.push(vec![r.to_string(), human_bytes(b), fit(b).into()]);
    }
    print!("{}", report::table(&rows));

    println!("\n-- Private Fock: 4 ranks, thread sweep (per-thread F) --");
    let mut rows = vec![vec!["threads".into(), "bytes/node".into(), "fits in".into()]];
    for t in [1usize, 8, 16, 32, 64] {
        let b = exact_bytes(EngineKind::PrivateFock, n, 15, 4, t);
        rows.push(vec![t.to_string(), human_bytes(b), fit(b).into()]);
    }
    print!("{}", report::table(&rows));

    println!("\n-- Shared Fock: 4 ranks, thread sweep (column buffers only) --");
    let mut rows = vec![vec!["threads".into(), "bytes/node".into(), "fits in".into()]];
    for t in [1usize, 8, 16, 32, 64] {
        let b = exact_bytes(EngineKind::SharedFock, n, 15, 4, t);
        rows.push(vec![t.to_string(), human_bytes(b), fit(b).into()]);
    }
    print!("{}", report::table(&rows));

    println!(
        "\nthe paper's story in one table: MPI-only replication explodes with ranks;\n\
         private Fock grows linearly with threads; shared Fock is flat (the column\n\
         buffers are {} per node at 64 threads).",
        human_bytes(2.0 * (n * 15) as f64 * 64.0 * 4.0 * 8.0)
    );
    Ok(())
}

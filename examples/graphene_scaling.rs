//! Single-node scaling study on real graphene workloads — the Figure
//! 3/4 companion, sweeping hardware threads and affinity on a simulated
//! KNL node with the engines' real task statistics.
//!
//! Run: cargo run --release --example graphene_scaling [-- --system 1.0]

use khf::chem::graphene::PaperSystem;
use khf::cluster::knl::Affinity;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system};
use khf::hf::memmodel::EngineKind;
use khf::util::cli::Args;

fn main() -> anyhow::Result<()> {
    khf::util::logging::init();
    let args = Args::from_env();
    let sys = PaperSystem::parse(args.get_or("system", "0.5"))
        .ok_or_else(|| anyhow::anyhow!("bad --system"))?;
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(sys, &cost)?;

    println!("single-node study: {} ({} shells, {} BFs)", sys.label(), stats.n_shells, stats.n_bf);
    println!("\n-- thread scaling (4 ranks, balanced affinity, quad-cache) --");
    let mut rows = vec![vec![
        "threads/rank".into(),
        "hw threads".into(),
        "private (s)".into(),
        "shared (s)".into(),
        "shared/private".into(),
    ]];
    for t in [1usize, 2, 4, 8, 16, 32, 64] {
        let m = Machine {
            threads_per_rank: t,
            mcdram_only: true,
            ..Machine::theta_hybrid(1)
        };
        let prf = simulate(EngineKind::PrivateFock, &stats, &m, &cost);
        let shf = simulate(EngineKind::SharedFock, &stats, &m, &cost);
        rows.push(vec![
            t.to_string(),
            (4 * t).to_string(),
            report::secs(prf.fock_seconds),
            report::secs(shf.fock_seconds),
            format!("{:.2}", shf.fock_seconds / prf.fock_seconds),
        ]);
    }
    print!("{}", report::table(&rows));

    println!("\n-- affinity effect at 16 threads/rank (shared Fock) --");
    let mut rows = vec![vec!["affinity".into(), "time (s)".into()]];
    for aff in Affinity::ALL {
        let m = Machine {
            threads_per_rank: 16,
            affinity: aff,
            mcdram_only: true,
            ..Machine::theta_hybrid(1)
        };
        let r = simulate(EngineKind::SharedFock, &stats, &m, &cost);
        rows.push(vec![aff.label().into(), report::secs(r.fock_seconds)]);
    }
    print!("{}", report::table(&rows));

    println!("\n-- engine breakdown at 4x64 (shared Fock) --");
    let m = Machine { mcdram_only: true, ..Machine::theta_hybrid(1) };
    let r = simulate(EngineKind::SharedFock, &stats, &m, &cost);
    let b = r.breakdown;
    for (k, v) in [
        ("compute", b.compute),
        ("screen", b.screen_tests),
        ("sync", b.sync),
        ("flush", b.flush),
        ("dlb", b.dlb),
        ("imbalance", b.imbalance),
        ("reduce", b.reduce_ranks + b.reduce_threads),
    ] {
        println!("   {k:10} {:8.4} s ({:4.1}%)", v, 100.0 * v / r.fock_seconds);
    }
    Ok(())
}

//! Multi-node Theta simulation driver — the Table 3 / Figures 6–7
//! companion with configurable system, node list and engine.
//!
//! Run: cargo run --release --example theta_simulation -- \
//!        [--system 2.0] [--nodes 4,16,64,256] [--iters 15]

use khf::chem::graphene::PaperSystem;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system};
use khf::hf::memmodel::EngineKind;
use khf::util::cli::Args;

fn main() -> anyhow::Result<()> {
    khf::util::logging::init();
    let args = Args::from_env();
    let sys = PaperSystem::parse(args.get_or("system", "0.5"))
        .ok_or_else(|| anyhow::anyhow!("bad --system"))?;
    let nodes: Vec<usize> = args.parse_list("nodes")?.unwrap_or_else(|| vec![4, 16, 64, 128]);
    let iters = args.parse_or("iters", 15.0f64)?;
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(sys, &cost)?;

    println!(
        "Theta simulation: {} — {} surviving ij tasks, {:.2e} quartets/iteration",
        sys.label(),
        stats.pairs.len(),
        stats.total_quartets as f64
    );
    let mut rows = vec![vec![
        "nodes".into(),
        "MPI (s)".into(),
        "r/n".into(),
        "PrF (s)".into(),
        "ShF (s)".into(),
        "ShF eff%".into(),
        "ShF imb".into(),
        "ShF GB/node".into(),
    ]];
    let mut shf_base: Option<(usize, f64)> = None;
    for &n in &nodes {
        let mpi = simulate(EngineKind::MpiOnly, &stats, &Machine::theta_mpi(n), &cost);
        let prf = simulate(EngineKind::PrivateFock, &stats, &Machine::theta_hybrid(n), &cost);
        let shf = simulate(EngineKind::SharedFock, &stats, &Machine::theta_hybrid(n), &cost);
        let (n0, t0) = *shf_base.get_or_insert((n, shf.fock_seconds));
        rows.push(vec![
            n.to_string(),
            report::secs(mpi.fock_seconds * iters),
            mpi.ranks_per_node_used.to_string(),
            report::secs(prf.fock_seconds * iters),
            report::secs(shf.fock_seconds * iters),
            report::pct(t0 * n0 as f64 / (shf.fock_seconds * n as f64)),
            format!("{:.2}", shf.rank_imbalance),
            format!("{:.1}", shf.bytes_per_node / 1e9),
        ]);
    }
    print!("{}", report::table(&rows));
    Ok(())
}

//! Quickstart — the end-to-end driver (DESIGN.md §4, last row).
//!
//! Exercises every layer of the stack on real workloads:
//!  1. full RHF on water through the **XLA path** (Rust integrals →
//!     zero-padded dense ERI → AOT Pallas `fock_jk` artifact on the
//!     PJRT CPU client → Rust Jacobi diagonalization → convergence),
//!  2. the same molecule through the paper's three threaded engines
//!     (identical energies = the correctness headline),
//!  3. a simulated Theta run of the 0.5 nm graphene system with the
//!     calibrated cost model (the paper's scaling headline).
//!
//! Run: cargo run --release --example quickstart   (after `make artifacts`)

use khf::basis::{BasisName, BasisSet};
use khf::chem::graphene::PaperSystem;
use khf::chem::molecules;
use khf::cluster::{simulate, CostModel, Machine};
use khf::coordinator::{report, stats_for_system};
use khf::hf::memmodel::EngineKind;
use khf::hf::mpi_only::MpiOnlyFock;
use khf::hf::private_fock::PrivateFock;
use khf::hf::serial::SerialFock;
use khf::hf::shared_fock::SharedFock;
use khf::runtime::{Runtime, XlaFockBuilder};
use khf::scf::RhfDriver;
use khf::util::{human_secs, logging};

fn main() -> anyhow::Result<()> {
    logging::init();
    let mol = molecules::water();
    let basis = BasisSet::assemble(&mol, BasisName::Sto3g)?;
    let driver = RhfDriver::default();

    println!("== 1. RHF through the three-layer XLA path (water / STO-3G) ==");
    let artifacts = Runtime::default_dir();
    if artifacts.join("fock2e_8.hlo.txt").exists() {
        let rt = Runtime::cpu(&artifacts)?;
        // One shell-pair store serves the dense tabulation and the SCF.
        let store = std::sync::Arc::new(khf::integrals::ShellPairStore::build(&basis));
        let mut xla = XlaFockBuilder::new_with_store(rt, &basis, &store)?;
        let r = driver.run_with_store(&mol, &basis, store, &mut xla)?;
        println!(
            "   E = {:.8} Ha in {} iterations (literature: -74.963) — Fock via Pallas/PJRT, {}",
            r.energy,
            r.iterations,
            human_secs(r.fock_build_seconds)
        );
    } else {
        println!("   [skipped — run `make artifacts` first]");
    }

    println!("\n== 2. The paper's engines agree to machine precision ==");
    let mut rows = vec![vec!["engine".into(), "config".into(), "energy (Ha)".into(), "iters".into()]];
    let r = driver.run(&mol, BasisName::Sto3g, &mut SerialFock::new())?;
    rows.push(vec!["serial".into(), "1".into(), format!("{:.10}", r.energy), r.iterations.to_string()]);
    let r = driver.run(&mol, BasisName::Sto3g, &mut MpiOnlyFock::new(4))?;
    rows.push(vec!["mpi-only (Alg 1)".into(), "4 ranks".into(), format!("{:.10}", r.energy), r.iterations.to_string()]);
    let r = driver.run(&mol, BasisName::Sto3g, &mut PrivateFock::new(2, 2))?;
    rows.push(vec!["private Fock (Alg 2)".into(), "2x2".into(), format!("{:.10}", r.energy), r.iterations.to_string()]);
    let r = driver.run(&mol, BasisName::Sto3g, &mut SharedFock::new(2, 2))?;
    rows.push(vec!["shared Fock (Alg 3)".into(), "2x2".into(), format!("{:.10}", r.energy), r.iterations.to_string()]);
    print!("{}", report::table(&rows));

    println!("\n== 3. Simulated Theta scaling, 0.5 nm graphene bilayer (calibrated) ==");
    let cost = CostModel::load_or_fallback("artifacts/calibration.toml");
    let stats = stats_for_system(PaperSystem::Nm05, &cost)?;
    let mut rows = vec![vec![
        "nodes".into(),
        "MPI-only (s)".into(),
        "private (s)".into(),
        "shared (s)".into(),
        "shared speedup vs MPI".into(),
    ]];
    for nodes in [1usize, 4, 16, 64] {
        let mpi = simulate(EngineKind::MpiOnly, &stats, &Machine::theta_mpi(nodes), &cost);
        let prf = simulate(EngineKind::PrivateFock, &stats, &Machine::theta_hybrid(nodes), &cost);
        let shf = simulate(EngineKind::SharedFock, &stats, &Machine::theta_hybrid(nodes), &cost);
        rows.push(vec![
            nodes.to_string(),
            report::secs(mpi.fock_seconds * 15.0),
            report::secs(prf.fock_seconds * 15.0),
            report::secs(shf.fock_seconds * 15.0),
            format!("{:.1}x", mpi.fock_seconds / shf.fock_seconds),
        ]);
    }
    print!("{}", report::table(&rows));
    println!("\nquickstart complete — all three layers composed.");
    Ok(())
}
